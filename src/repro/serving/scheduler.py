"""Parallel-combining continuous-batching scheduler (the production
integration of the paper's technique — DESIGN.md §3).

Decode serving is exactly the paper's workload: many concurrent request
streams share one structure (the device batch slots / KV cache) and the
system must choose between fine-grained dispatch (one device program per
request — the "fine-grained locking" analogue) and combining.

This scheduler IS Listing 1:

* a session thread with a new request publishes it (``ParallelCombiner``
  publication list) and tries the global lock;
* whichever thread wins becomes the **combiner**: it drains the publication
  list, *orders* the pending requests with the paper's §4 **batched priority
  queue** (keyed by deadline — all pending keys are inserted and the
  ``max_batch`` smallest extracted in ONE device batch-apply), stacks the
  chosen requests into a dense batch and launches ONE SPMD ``step_fn`` over
  the mesh;
* the waiting clients' "free cycles" are the device lanes: a combined batch
  of B requests runs on the same program at ~the cost of one.

Requests not selected by the deadline-PQ stay PUSHED and are picked up by
the next combining pass (continuous batching).

``SerialScheduler`` is the fine-grained baseline: every request dispatches
its own device program under a plain mutex (the "single global lock, no
combining" analogue) — the benchmark compares the two (EXPERIMENTS §Paper).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.batched_pq import BatchedPriorityQueue
from repro.core.combining import ParallelCombiner, Request, Status


@dataclass
class BatchRequest:
    """One serving request: an input row + a deadline priority key."""

    inputs: Any                       # per-request input (np array row / dict)
    deadline: float = 0.0             # smaller = more urgent
    submitted_at: float = field(default_factory=time.monotonic)


class PCScheduler:
    """Parallel-combining scheduler around a batched ``step_fn``.

    Args:
      step_fn: callable taking a list of request inputs (length ≤ max_batch)
        and returning a list of per-request outputs.  In production this is
        the jitted SPMD ``serve_step`` (stack → one device program →
        unstack); the scheduler is agnostic.
      max_batch: device batch capacity per combining pass.
      use_pq: order pending requests by deadline with the §4 batched PQ
        (True) or FIFO (False) — the PQ path exercises the paper's batched
        data structure inside the serving layer.
    """

    def __init__(self, step_fn: Callable[[List[Any]], Sequence[Any]],
                 max_batch: int = 16, use_pq: bool = True,
                 pq_capacity: int = 1 << 16):
        self.step_fn = step_fn
        self.max_batch = max_batch
        self.use_pq = use_pq
        if use_pq:
            self._pq = BatchedPriorityQueue(pq_capacity,
                                            c_max=min(max_batch, 64))
            self._key_map: Dict[float, List[Request]] = {}
            self._key_lock = threading.Lock()
        self.engine = ParallelCombiner(self._combiner_code,
                                       self._client_code)
        # instrumentation
        self.batches: List[int] = []

    # -- Listing-1 plumbing -------------------------------------------------
    def _order(self, requests: List[Request]) -> List[Request]:
        if not self.use_pq or len(requests) <= 1:
            return sorted(requests, key=lambda r: r.input.deadline)
        # §4 batched PQ: one combined batch inserts every pending deadline
        # key and extracts the max_batch smallest — a single device program.
        # Keys are quantized to f32 (the device heap dtype) so extracted
        # values round-trip exactly to the submission keys.
        keys = [float(np.float32(r.input.deadline)) for r in requests]
        with self._key_lock:
            for r, k in zip(requests, keys):
                self._key_map.setdefault(k, []).append(r)
            self._pq.apply(0, keys)                     # insert all
            got = self._pq.apply(min(len(requests), self.max_batch), [])
            chosen: List[Request] = []
            for k in got:
                if k is None:
                    continue
                chosen.append(self._key_map[float(k)].pop(0))
            # drain the unchosen keys (those requests stay PUSHED and are
            # re-inserted on the next combining pass)
            n_left = len(requests) - len(chosen)
            if n_left:
                self._pq.apply(n_left, [])
            self._key_map.clear()
        return chosen

    def _combiner_code(self, engine: ParallelCombiner,
                       requests: List[Request]) -> None:
        if not requests:
            return
        chosen = self._order(requests)[: self.max_batch]
        self.batches.append(len(chosen))
        outs = self.step_fn([r.input.inputs for r in chosen])
        for r, o in zip(chosen, outs):
            r.res = o
            r.status = Status.FINISHED
        # unchosen requests remain PUSHED → next combining pass serves them

    def _client_code(self, engine: ParallelCombiner, r: Request) -> None:
        return                       # device lanes did the work

    # -- public API ----------------------------------------------------------
    def submit(self, inputs: Any, deadline: float = 0.0) -> Any:
        """Blocking submit from a session thread; returns the output."""
        return self.engine.execute(
            "serve", BatchRequest(inputs=inputs, deadline=deadline))

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batches)) if self.batches else 0.0


class SerialScheduler:
    """Fine-grained baseline: one device dispatch per request, mutex-guarded."""

    def __init__(self, step_fn: Callable[[List[Any]], Sequence[Any]]):
        self.step_fn = step_fn
        self._lock = threading.Lock()
        self.batches: List[int] = []

    def submit(self, inputs: Any, deadline: float = 0.0) -> Any:
        with self._lock:
            self.batches.append(1)
            return self.step_fn([inputs])[0]
