"""Async parallel-combining continuous-batching scheduler (DESIGN.md §3, §9).

Decode serving is exactly the paper's workload: many concurrent request
streams share one structure (the device batch slots / KV cache) and the
system must choose between fine-grained dispatch (one device program per
request — the "fine-grained locking" analogue) and combining.

The first revision of this scheduler was a literal Listing 1: session
threads spin on a publication list and whichever wins the global lock
becomes the combiner.  This revision keeps the paper's *explicit
synchronization* (one combiner, batched application) but moves it onto a
production async engine:

* ``submit_async`` is non-blocking and returns a ``concurrent.futures``
  future — the publication step is an O(1) append under a condition
  variable, no spinning;
* a **dedicated combiner loop** drains the publication buffer, orders the
  pending requests by deadline on the **K-sharded batched priority queue**
  (DESIGN.md §9 — inserts routed across shards, extraction is a K-way
  merge, all as vmapped device programs) and hands the chosen batch to the
  device;
* the combiner is **pipelined** against the device: while device pass N is
  in flight, the combiner is already collecting and ordering pass N+1
  (a depth-1 handoff queue), so host-side ordering cost hides behind
  device compute;
* PQ device programs are **sync-free** (DESIGN.md §10): publishing new
  keys uses ``apply_async`` — the insert dispatch returns immediately with
  the result left on device — and the extraction apply performs exactly
  one blocking host transfer, so the combiner loop pays at most one
  device round-trip per pass instead of one per PQ slice;
* the PQ keys live in a **persistent key→request table**: unchosen
  requests simply *stay* in the device-resident PQ across passes (the
  previous revision cleared and re-inserted every pending key each pass —
  ``O(pending)`` device work per pass; now each key is inserted once and
  extracted once);
* an **elimination pre-pass** (DESIGN.md §12) serves new requests that
  provably undercut every resident key straight from the host — the
  publish (insert) and the pick (extractMin) annihilate before touching
  the device, so a drained queue costs ZERO PQ device programs;
* **adaptive round batching** (DESIGN.md §12): when the backlog exceeds
  one device batch, the combiner asks the PQ for R = ⌈backlog/max_batch⌉
  (capped at ``rounds_cap``) extraction rounds in ONE fused
  ``apply_rounds`` dispatch — publish round + R extract rounds all run
  inside a single donated ``lax.scan`` program, and the R chosen batches
  are handed to the device loop back-to-back.

``SerialScheduler`` is the fine-grained baseline: every request dispatches
its own device program under a plain mutex (the "single global lock, no
combining" analogue) — the benchmark compares the two (EXPERIMENTS §Paper).
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.combining import (ALL_TIERS, TIER_DEVICE, TIER_ELIMINATE,
                                  TIER_HOST, TierRouter)
from repro.core.faults import (CircuitBreaker, DispatchGuard, FaultPlan,
                               InjectedCombinerKill)
from repro.core.sharded_pq import ShardedBatchedPQ, host_key

_SENTINEL = object()


def _fail_future(f: Future, exc: BaseException) -> None:
    """Fail ``f`` unless already resolved.  The done() pre-check cannot
    be atomic against a concurrent ``cancel()`` — swallowing the
    InvalidStateError keeps that race from killing a worker loop."""
    try:
        if not f.done():
            f.set_exception(exc)
    except Exception:
        pass


def _resolve_future(f: Future, value: Any) -> None:
    """Resolve ``f`` unless already resolved (same race note as above)."""
    try:
        if not f.done():
            f.set_result(value)
    except Exception:
        pass


@dataclass
class BatchRequest:
    """One serving request: an input row + a deadline priority key."""

    inputs: Any                       # per-request input (np array row / dict)
    deadline: float = 0.0             # smaller = more urgent
    submitted_at: float = field(default_factory=time.monotonic)


@dataclass
class _Entry:
    """A published request inside the scheduler (request + its future)."""

    req: BatchRequest
    future: Future
    key: float = 0.0                  # f32-quantized deadline (PQ dtype)
    epoch: int = 0                    # per-entry id (exactly-once recovery)


class PCScheduler:
    """Async parallel-combining scheduler around a batched ``step_fn``.

    Args:
      step_fn: callable taking a list of request inputs (length ≤ max_batch)
        and returning a list of per-request outputs.  In production this is
        the jitted SPMD ``serve_step`` (stack → one device program →
        unstack); the scheduler is agnostic.
      max_batch: device batch capacity per combining pass.
      use_pq: order pending requests by deadline with the sharded batched
        PQ (True) or FIFO (False) — the PQ path exercises the paper's
        batched data structure inside the serving layer.
      pq_capacity: per-shard heap capacity of the deadline PQ.
      n_shards: shard count K of the deadline PQ.
      pipeline: overlap combiner-side collection/ordering of pass N+1 with
        the in-flight device step of pass N (depth-1 handoff).  False runs
        the device step inline on the combiner thread (debug mode).
      pq_use_pallas: run the deadline PQ's combining passes through the
        shard-grid Pallas kernels (DESIGN.md §10).
      pq_donate: zero-copy (donated) PQ dispatch (default); False is the
        copy-per-pass ablation twin (EXPERIMENTS §Ablations).
      pq_placement: shard layout for the deadline PQ (DESIGN.md §18).
        None keeps the stacked leading-axis-K default; a
        ``MeshPlacement`` places the K shards across its device mesh and
        routes the fused passes through the shard_map collective twins
        (``serve.py --mesh-shards``).  Mutually exclusive with
        ``pq_use_pallas`` (the kernels assume the stacked layout).
      rounds_cap: cap R on the adaptive multi-round fused dispatch
        (DESIGN.md §12) — one ordering pass may choose up to
        ``rounds_cap · max_batch`` requests (eliminated + extracted) and
        hand them off as up to ``rounds_cap`` device batches; it also
        bounds the priority-inversion window (requests arriving while the
        chosen batches drain cannot preempt them).
      tier: ordering execution tier (DESIGN.md §14).  ``eliminate`` (the
        default, the pre-§14 behavior) runs the elimination pre-pass and
        sends survivors through the device PQ; ``device`` skips the
        pre-pass; ``host`` keeps survivors in a host-side staging pool
        and only touches the device PQ to drain keys already resident
        there; ``auto`` lets a :class:`TierRouter` pick per ordering pass
        from its online cost model (decisions in ``tier_decisions``).
      router: optional externally-owned ``TierRouter`` (shared cost
        model / injectable clock for tests); built internally when None.
      fault_plan: optional :class:`FaultPlan` (DESIGN.md §15).  Hooks the
        combiner loop (kill / latency-spike injection per ordering pass)
        and wraps the deadline PQ's device dispatch in a transactional
        :class:`DispatchGuard` whose circuit breaker also vetoes the
        device/eliminate ordering tiers (graceful degradation to host).
      supervise: run a supervisor thread that restarts a dead combiner
        loop and re-queues every unserved entry exactly once (per-entry
        epoch ids dedupe across all internal queues).
    """

    def __init__(self, step_fn: Callable[[List[Any]], Sequence[Any]],
                 max_batch: int = 16, use_pq: bool = True,
                 pq_capacity: int = 1 << 16, n_shards: int = 4,
                 pipeline: bool = True, pq_use_pallas: bool = False,
                 pq_donate: bool = True, pq_placement=None,
                 rounds_cap: int = 4,
                 tier: str = "eliminate",
                 router: Optional[TierRouter] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 supervise: bool = True):
        self.step_fn = step_fn
        self.max_batch = max_batch
        self.use_pq = use_pq
        self.pipeline = pipeline
        self.rounds_cap = max(1, int(rounds_cap))
        if tier not in ("auto",) + tuple(ALL_TIERS):
            raise ValueError(f"unknown tier {tier!r}")
        self.fault_plan = fault_plan
        self.takeovers = 0             # combiner-loop restarts (DESIGN.md §15)
        self.breaker: Optional[CircuitBreaker] = None
        self._next_epoch = 0
        self._inflight = 0             # device steps currently executing
        self._sched_passes = 0         # fault-probe pass counter
        if use_pq:
            pq_guard = None
            if fault_plan is not None:
                # one breaker shared between the PQ's dispatch guard and
                # the ordering-tier router: repeated dispatch failures
                # open it, which both trips the guard's fallback AND
                # degrades ordering to the host tier until a probe heals.
                self.breaker = CircuitBreaker()
                pq_guard = DispatchGuard(fault_plan, breaker=self.breaker)
            self._pq_ctor = dict(capacity=pq_capacity,
                                 c_max=min(max_batch, 64),
                                 n_shards=n_shards,
                                 use_pallas=pq_use_pallas,
                                 donate=pq_donate,
                                 placement=pq_placement,
                                 guard=pq_guard)
            self._pq = ShardedBatchedPQ(**self._pq_ctor)
            # persistent key→request table: a key is inserted into the
            # device PQ exactly once and stays there until extracted
            self._table: Dict[float, Deque[_Entry]] = {}
            self._queued = 0           # keys currently resident in the PQ
            self._resident: List[float] = []   # lazy min-heap of PQ keys
            # host-tier staging pool: ordered entries NOT published to the
            # device PQ; re-merged into the next ordering pass
            self._staged: List[_Entry] = []
            self.router = router or TierRouter(
                "sched", ALL_TIERS,
                force=None if tier == "auto" else tier)
            self.tier_decisions = self.router.tier_decisions
            if self.breaker is not None:
                for t in (TIER_DEVICE, TIER_ELIMINATE):
                    self.router.attach_breaker(t, self.breaker)
        self._backlog: Deque[_Entry] = deque()   # FIFO-mode leftovers
        self._pending: Deque[_Entry] = deque()   # publication buffer
        self._cond = threading.Condition()
        self._closed = False
        # instrumentation
        self.batches: List[int] = []
        self.passes = 0
        self.eliminated = 0            # requests served without PQ work
        self.pq_dispatches = 0         # fused PQ programs dispatched
        self.pq_rounds = 0             # combining rounds those carried

        self._handoff: "queue.Queue[Any]" = queue.Queue(maxsize=1)
        self._combiner = threading.Thread(
            target=self._combiner_loop, name="pc-combiner", daemon=True)
        self._device: Optional[threading.Thread] = None
        if pipeline:
            self._device = threading.Thread(
                target=self._device_loop, name="pc-device", daemon=True)
            self._device.start()
        self._combiner.start()
        self._supervisor: Optional[threading.Thread] = None
        if supervise:
            self._supervisor = threading.Thread(
                target=self._supervisor_loop, name="pc-supervisor",
                daemon=True)
            self._supervisor.start()

    @property
    def rounds_per_dispatch(self) -> float:
        """Mean combining rounds per fused PQ dispatch (DESIGN.md §17
        amortization factor; 0.0 before the first dispatch)."""
        return (self.pq_rounds / self.pq_dispatches
                if self.pq_dispatches else 0.0)

    # -- public API ----------------------------------------------------------
    def submit_async(self, inputs: Any, deadline: float = 0.0) -> Future:
        """Non-blocking submit; returns a future for the request's output.

        Raises ``RuntimeError`` immediately after :meth:`close` — and,
        defensively, if the combiner thread is no longer alive (a request
        must never enqueue onto a dead combiner loop, where its future
        could hang forever)."""
        if deadline != deadline:        # reject NaN at the client boundary
            raise ValueError("deadline must not be NaN")
        f: Future = Future()
        ent = _Entry(BatchRequest(inputs=inputs, deadline=deadline), f)
        with self._cond:
            alive = self._combiner.is_alive() or (
                self._supervisor is not None and self._supervisor.is_alive())
            if self._closed or not alive:
                raise RuntimeError("scheduler is closed")
            ent.epoch = self._next_epoch
            self._next_epoch += 1
            self._pending.append(ent)
            self._cond.notify()
        return f

    def submit(self, inputs: Any, deadline: float = 0.0) -> Any:
        """Blocking submit from a session thread; returns the output."""
        return self.submit_async(inputs, deadline).result()

    def close(self) -> None:
        """Drain outstanding requests, then stop the worker threads.

        Every future submitted before ``close`` resolves by the time it
        returns: requests already collected are served, and anything
        still unserved when the workers stop (e.g. because a worker
        thread died) is failed with ``RuntimeError`` instead of leaving
        its caller hanging.  A concurrent second ``close`` waits for the
        shutdown to complete instead of returning early."""
        with self._cond:
            first = not self._closed
            self._closed = True
            self._cond.notify_all()
        if self._supervisor is not None:
            self._supervisor.join()
        # the supervisor may have replaced the combiner right up until it
        # observed _closed — join whichever thread holds the role now
        while True:
            c = self._combiner
            c.join()
            if c is self._combiner:
                break
        if self._device is not None:
            if first:
                self._handoff.put(_SENTINEL)
            self._device.join()
        # an in-flight device step must finish and resolve its futures
        # BEFORE the doomed-future sweep: close() must never fail a
        # request the device is about to answer.
        with self._cond:
            while self._inflight:
                self._cond.wait()
        # safety net: no caller may hang on a future we will never serve.
        # The workers are joined, but a CONCURRENT second close() runs
        # this same sweep — take the lock so the two don't race on the
        # queues/table (uncontended: submitters raise under it already).
        with self._cond:
            doomed = list(self._pending) + list(self._backlog)
            self._pending.clear()
            self._backlog.clear()
            if self.use_pq:
                for bucket in self._table.values():
                    doomed.extend(bucket)
                self._table.clear()
                doomed.extend(self._staged)
                self._staged = []
                self._queued = 0
                self._resident = []
        for ent in doomed:
            _fail_future(ent.future, RuntimeError(
                "scheduler closed before the request was served"))

    def __enter__(self) -> "PCScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def mean_batch(self) -> float:
        return float(np.mean(self.batches)) if self.batches else 0.0

    # -- combiner loop -------------------------------------------------------
    def _has_leftovers(self) -> bool:
        if self.use_pq:
            return self._queued > 0 or bool(self._staged)
        return bool(self._backlog)

    def _combiner_loop(self) -> None:
        while True:
            with self._cond:
                while (not self._closed and not self._pending
                       and not self._has_leftovers()):
                    self._cond.wait()
                if (self._closed and not self._pending
                        and not self._has_leftovers()):
                    return
                new = list(self._pending)
                self._pending.clear()
            if self.fault_plan is not None:
                self._sched_passes += 1
                try:
                    self.fault_plan.on_combiner_pass(self._sched_passes)
                except InjectedCombinerKill:
                    # crash emulation: push the just-collected requests
                    # back unserved and die with them still queued — the
                    # supervisor re-queues everything exactly-once (epoch
                    # ids) and restarts the loop.
                    with self._cond:
                        self._pending.extendleft(reversed(new))
                    raise
            try:
                chosen_rounds = self._order(new)
            except BaseException as exc:
                # ordering failure must not kill the combiner silently:
                # fail every affected future (ordering state may be
                # inconsistent, so flush leftovers too) and keep serving
                self._abort_pending(new, exc)
                continue
            for chosen in chosen_rounds:
                self.passes += 1
                self.batches.append(len(chosen))
                if self.pipeline:
                    self._handoff.put(chosen)  # blocks at pipeline depth 1
                else:
                    self._run_batch(chosen)

    def _abort_pending(self, new: List[_Entry], exc: BaseException) -> None:
        doomed = list(new) + list(self._backlog)
        self._backlog.clear()
        if self.use_pq:
            for bucket in self._table.values():
                doomed.extend(bucket)
            self._table.clear()
            doomed.extend(self._staged)
            self._staged = []
            self._queued = 0
            self._resident = []
            # the device PQ may hold keys for the doomed requests (and be
            # mid-batch inconsistent) — rebuild it from scratch
            self._pq = ShardedBatchedPQ(**self._pq_ctor)
        for ent in doomed:
            _fail_future(ent.future, exc)

    # -- supervisor (DESIGN.md §15) ------------------------------------------
    def _supervisor_loop(self) -> None:
        while True:
            c = self._combiner
            c.join(timeout=0.05)
            with self._cond:
                if self._closed:
                    return
                if c.is_alive() or c is not self._combiner:
                    continue
            self._recover(c)

    def _recover(self, dead: threading.Thread) -> None:
        """Restart a dead combiner loop, re-queueing every unserved entry
        exactly once: entries are gathered from ALL internal queues (the
        publication buffer, the FIFO backlog, the key table and the host
        staging pool), deduped by per-entry epoch id, and replayed in
        submission order.  Entries whose future already resolved (e.g. an
        in-flight device step finished while the combiner was down) are
        skipped — a request is never applied twice."""
        with self._cond:
            if self._closed or self._combiner is not dead:
                return
            entries = list(self._pending) + list(self._backlog)
            self._pending.clear()
            self._backlog.clear()
            if self.use_pq:
                for bucket in self._table.values():
                    entries.extend(bucket)
                self._table.clear()
                entries.extend(self._staged)
                self._staged = []
                self._queued = 0
                self._resident = []
                # the device PQ may hold keys of recovered requests (and
                # may be mid-pass inconsistent) — rebuild it from scratch;
                # _pq_ctor carries the dispatch guard, so the rebuilt PQ
                # stays transactional under the active fault plan
                self._pq = ShardedBatchedPQ(**self._pq_ctor)
            seen: set = set()
            requeue: List[_Entry] = []
            for ent in sorted(entries, key=lambda e: e.epoch):
                if ent.epoch in seen or ent.future.done():
                    continue
                seen.add(ent.epoch)
                requeue.append(ent)
            self._pending.extend(requeue)
            self.takeovers += 1
            if self.fault_plan is not None:
                self.fault_plan.counters.bump("takeovers")
            self._combiner = threading.Thread(
                target=self._combiner_loop, name="pc-combiner", daemon=True)
            self._combiner.start()
            self._cond.notify_all()

    def fault_counters(self) -> Dict[str, Any]:
        """Robustness counters surfaced to ops layers (DESIGN.md §15)."""
        out: Dict[str, Any] = {"scheduler_takeovers": self.takeovers}
        if self.fault_plan is not None:
            out.update(self.fault_plan.counters.snapshot())
        if self.breaker is not None:
            out["breaker_state"] = self.breaker.state
        return out

    def _peek_resident(self) -> Optional[float]:
        """Smallest key still resident in the device PQ (lazy min-heap:
        keys whose table bucket drained are popped on the way)."""
        h = self._resident
        while h and h[0] not in self._table:
            heapq.heappop(h)
        return h[0] if h else None

    def _order(self, new: List[_Entry]) -> List[List[_Entry]]:
        """One ordering pass: up to ``rounds_cap`` most-urgent device
        batches (each ≤ max_batch), leftovers stay queued.

        Elimination pre-pass + fused rounds (DESIGN.md §12): new keys that
        undercut every resident key are chosen straight from the host —
        their insert and their extract annihilate, zero PQ device work
        (with nothing resident that is EVERY new request, the drained-
        queue steady state).  Whatever survives goes to the device as ONE
        ``apply_rounds`` dispatch: a publish round for the surviving new
        keys plus ⌈want/max_batch⌉ extraction rounds, all inside a single
        donated scan program with one blocking fetch."""
        if not self.use_pq:
            self._backlog.extend(new)
            n = min(self.max_batch, len(self._backlog))
            return [[self._backlog.popleft() for _ in range(n)]] if n \
                else []
        # tier decision (DESIGN.md §14): ONE routing choice — and one
        # cost-model observation — per ordering pass
        width = len(new) + len(self._staged)
        t = self.router.choose(width, 0.0)
        with self.router.timed(t, width, 0.0, n_ops=max(1, width)):
            return self._order_tiered(new, t)

    def _order_tiered(self, new: List[_Entry],
                      tier: str) -> List[List[_Entry]]:
        budget = self.rounds_cap * self.max_batch
        # host_key applies the device's full key quantization (f32 +
        # flush-to-zero + finite clamp) so extracted keys hit the table.
        for ent in new:
            ent.key = host_key(ent.req.deadline)
        if self._staged:
            # host-tier staging pool: unpublished survivors of earlier
            # passes re-enter the ordering here (already quantized)
            new = new + self._staged
            self._staged = []
        new = sorted(new, key=lambda e: e.key)
        min_res = self._peek_resident()
        n_elim = 0
        if tier != TIER_DEVICE:          # device tier = no pre-pass
            while (n_elim < len(new) and n_elim < budget
                   and (min_res is None or new[n_elim].key <= min_res)):
                n_elim += 1
        elim, rest = new[:n_elim], new[n_elim:]
        self.eliminated += n_elim
        chosen: List[_Entry] = list(elim)
        if tier == TIER_HOST:
            # host tier: survivors stay OFF the device PQ (staged for the
            # next pass — they can't be served yet: their keys sit above
            # the device-resident minimum, or the pass budget is spent).
            # Device work only to drain keys already resident — that cost
            # is charged to the host decision, the natural switch penalty.
            self._staged = rest
            rest = []
            want = min(self._queued, budget - n_elim)
        else:
            want = min(self._queued + len(rest), budget - n_elim)
        if rest or want:
            # publish the surviving NEW keys only — everything already in
            # the device PQ stays there (persistent table; no re-insert
            # churn) — and extract the `want` most urgent, all in ONE
            # fused multi-round dispatch.
            for ent in rest:
                self._table.setdefault(ent.key, deque()).append(ent)
                heapq.heappush(self._resident, ent.key)
            self._queued += len(rest)
            rounds: List = [(0, [e.key for e in rest])] if rest else []
            n_ins_rounds = len(rounds)
            left = want
            while left > 0:
                ne = min(left, self.max_batch)
                rounds.append((ne, []))
                left -= ne
            try:
                handles = self._pq.apply_rounds_async(rounds)
            except ValueError as exc:
                # occupancy-guard refusal (the deadline PQ would overflow
                # a shard).  The refusal is ATOMIC on the PQ side —
                # nothing reached the device and the mirror is untouched
                # — so fail ONLY the new requests: resident entries, the
                # lazy min-heap and the device PQ stay exactly as they
                # were, and the next pass keeps draining them.  (The
                # heap may keep stale copies of the refused keys; the
                # lazy pop in _peek_resident discards keys whose table
                # bucket is gone.)
                for ent in rest:
                    bucket = self._table.get(ent.key)
                    if bucket is not None:
                        try:
                            bucket.remove(ent)
                        except ValueError:
                            pass
                        if not bucket:
                            del self._table[ent.key]
                    _fail_future(ent.future, exc)
                self._queued -= len(rest)
                return [chosen[i : i + self.max_batch]
                        for i in range(0, len(chosen), self.max_batch)]
            self.pq_dispatches += 1
            self.pq_rounds += len(rounds)
            lost = False
            for h in handles[n_ins_rounds:]:
                for k in h.result():    # first consume pays the one fetch
                    if k is None:
                        # the device PQ is empty though bookkeeping says
                        # otherwise — reconcile instead of livelocking,
                        # and fail any requests whose keys were lost
                        self._queued = 0
                        self._resident = []
                        stranded = [e for b in self._table.values()
                                    for e in b]
                        self._table.clear()
                        for ent in stranded:
                            _fail_future(ent.future, RuntimeError(
                                "deadline key lost from the device PQ"))
                        lost = True
                        break
                    self._queued -= 1
                    bucket = self._table.get(float(k))
                    if bucket is None:
                        continue    # stale key flushed by an abort
                    chosen.append(bucket.popleft())
                    if not bucket:
                        del self._table[float(k)]
                if lost:
                    break
        # eliminated keys undercut every resident key and both streams
        # are ascending — the concatenation is globally urgency-ordered
        return [chosen[i : i + self.max_batch]
                for i in range(0, len(chosen), self.max_batch)]

    # -- device side ---------------------------------------------------------
    def _device_loop(self) -> None:
        while True:
            batch = self._handoff.get()
            if batch is _SENTINEL:
                return
            self._run_batch(batch)

    def _run_batch(self, batch: List[_Entry]) -> None:
        with self._cond:
            self._inflight += 1
        try:
            outs = list(self.step_fn([e.req.inputs for e in batch]))
            for ent, out in zip(batch, outs):
                _resolve_future(ent.future, out)   # client may have cancelled
            if len(outs) < len(batch):
                # a short return must not strand the tail forever
                raise RuntimeError(
                    f"step_fn returned {len(outs)} outputs for a batch "
                    f"of {len(batch)}")
        except BaseException as exc:   # propagate to every waiting client
            for ent in batch:
                _fail_future(ent.future, exc)
        finally:
            with self._cond:
                self._inflight -= 1
                self._cond.notify_all()


class SerialScheduler:
    """Fine-grained baseline: one device dispatch per request, mutex-guarded."""

    def __init__(self, step_fn: Callable[[List[Any]], Sequence[Any]]):
        self.step_fn = step_fn
        self._lock = threading.Lock()
        self.batches: List[int] = []

    def submit(self, inputs: Any, deadline: float = 0.0) -> Any:
        with self._lock:
            self.batches.append(1)
            return self.step_fn([inputs])[0]
