from .scheduler import (BatchRequest, PCScheduler, SerialScheduler)  # noqa: F401
