"""Paper Thm 4 — batch application cost O(c log c + log n).

Measures device wall time of ONE jitted ``apply_batch`` as a function of
(a) batch size c at fixed heap size n, and (b) heap size n at fixed c.
The theorem predicts near-linear growth in c (c log c) and ~flat growth in
n (log n) — the log n term is the sift/insert path length.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.batched_pq import BatchedPriorityQueue, apply_batch

from .common import save


def _time_apply(pq, ne, ins, iters=20):
    buf = np.full((pq.c_max,), np.inf, np.float32)
    buf[:len(ins)] = ins
    buf = jnp.asarray(buf)
    ne_, ni_ = jnp.int32(ne), jnp.int32(len(ins))
    # apply_batch DONATES the state (DESIGN.md §10) — thread it through
    # the loop instead of reusing the (now freed) input buffers.  With
    # ne == len(ins) the heap size is invariant, so every timed pass does
    # identical work on a same-shaped heap.
    state, _, _ = apply_batch(pq.state, ne_, buf, ni_, c_max=pq.c_max)
    state.a.block_until_ready()      # warmup + compile
    t0 = time.perf_counter()
    for _ in range(iters):
        state, vals, k = apply_batch(state, ne_, buf, ni_, c_max=pq.c_max)
        state.a.block_until_ready()
    pq.state = state                 # keep the wrapper coherent
    return (time.perf_counter() - t0) / iters


def bench_scaling(n_fixed=1 << 16, c_list=(2, 4, 8, 16, 32, 64),
                  c_fixed=16, n_list=(1 << 10, 1 << 13, 1 << 16, 1 << 19),
                  seed=0):
    rng = np.random.default_rng(seed)
    results = {"vary_c": [], "vary_n": []}

    for c in c_list:
        vals = rng.uniform(0, 1e6, n_fixed).astype(np.float32)
        pq = BatchedPriorityQueue(2 * n_fixed, c_max=c, values=vals)
        ins = rng.uniform(0, 1e6, c // 2).astype(np.float32)
        dt = _time_apply(pq, c - c // 2, ins)
        results["vary_c"].append({"c": c, "n": n_fixed,
                                  "us_per_batch": round(dt * 1e6, 1),
                                  "us_per_op": round(dt * 1e6 / c, 2)})
        print(f"[scaling] n={n_fixed} c={c:3d}: {dt*1e6:8.1f} us/batch "
              f"({dt*1e6/c:6.2f} us/op)")

    for n in n_list:
        vals = rng.uniform(0, 1e6, n).astype(np.float32)
        pq = BatchedPriorityQueue(2 * n, c_max=c_fixed, values=vals)
        ins = rng.uniform(0, 1e6, c_fixed // 2).astype(np.float32)
        dt = _time_apply(pq, c_fixed - c_fixed // 2, ins)
        results["vary_n"].append({"c": c_fixed, "n": n,
                                  "us_per_batch": round(dt * 1e6, 1)})
        print(f"[scaling] c={c_fixed} n={n:7d}: {dt*1e6:8.1f} us/batch")

    # Thm-4 shape checks: us/op should not grow faster than ~log c;
    # us/batch should grow sub-linearly in n (log n)
    c_times = [r["us_per_batch"] for r in results["vary_c"]]
    n_times = [r["us_per_batch"] for r in results["vary_n"]]
    results["c_growth"] = round(c_times[-1] / c_times[0], 2)
    results["n_growth"] = round(n_times[-1] / n_times[0], 2)
    print(f"[scaling] batch-time growth over {c_list[0]}→{c_list[-1]} ops: "
          f"{results['c_growth']}x (linear would be {c_list[-1]//c_list[0]}x)")
    print(f"[scaling] batch-time growth over {n_list[0]}→{n_list[-1]} heap: "
          f"{results['n_growth']}x (512x data growth)")
    save("bench_batch_scaling", results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args(argv)
    if a.quick:
        bench_scaling(n_fixed=1 << 13, c_list=(2, 8, 32),
                      n_list=(1 << 10, 1 << 13, 1 << 16))
    else:
        bench_scaling()


if __name__ == "__main__":
    main()
