"""Paper Fig. 1 — concurrent dynamic-graph throughput.

Workloads (paper §5.1): *Tree* (one random spanning tree, half its edges
prepopulated) and *Forest* (10 random trees); each thread applies
AreConnected with probability c% and Insert/Delete of a tree edge with
(100-c)/2% each, c ∈ {50, 90, 100}.

Implementations:

* ``PC host`` — the PR-2-era host tier: ``DynamicGraph`` (Python edge set,
  full O(E log V) XLA rebuild per update batch) under the §3.3 batched
  read combining.  This is the baseline the device tier must beat.
* ``PC-K{1,4,8}`` — the device-resident ``DeviceGraph`` (DESIGN.md §11):
  donated edge-buffer passes, K-way sharded label propagation, and the
  insert-only union-find fast path, under the same combining transform.
* ``PC-K4 nodonate`` / ``PC-K4 pallas`` — ablation twins (EXPERIMENTS
  §Ablations): copy-per-pass dispatch, and label rebuilds through the
  ``grid=(K,)`` Pallas kernel (interpret mode off-TPU).
* ``PC-K{K} mesh`` — the DESIGN.md §18 placement twin (opt-in via
  ``--impls``): the connectivity rebuild's scatter-min fixpoint runs
  with the edge list partitioned across the combining mesh, label
  merges via ``lax.pmin``; rows carry ``device_count``.
* ``PC-K4 guarded`` — the fault-free transactional-guard twin
  (DESIGN.md §15; EXPERIMENTS §Robustness): snapshot per pass, no plan.
* ``PC-K4 megapass`` / ``PC-K4 alternating`` — the §17 fused megapass
  pair (ISSUE 9): async-session clients publish to a
  ``MegapassCombiner``; up to ``rounds_cap`` mixed insert/delete/
  connected rounds ride ONE donated scan dispatch (vs one program per
  round); both rows report ``rounds_per_dispatch``.
* ``Lock`` (global mutex), ``RW Lock``, ``FC`` (flat combining) — the
  paper's host baselines.

The paper's claim: PC > {Lock, RW Lock, FC} and the gap grows with both
thread count and read share, because the combined read batch costs ONE
vectorized device call regardless of batch size.  The device tier's
claim on top (BENCH_graph.json): at read share ≥ 90% the fast-path
refresh + zero-copy edge passes beat the host tier's unconditional full
rebuild.
"""
from __future__ import annotations

import argparse
import numpy as np

from repro.core.device_graph import DeviceGraph
from repro.core.dynamic_graph import DynamicGraph
from repro.core.flat_combining import flat_combining
from repro.core.locks import LockDS, RWLockDS
from repro.core.read_opt import adaptive_read_engine, batched_read_optimized

from ._timing import measure
from .common import save

# update-slice width: combining passes carry ≤ threads updates, and the
# presence test is an O(c_max · capacity) broadcast compare — keep it tight
C_MAX = 16

DEFAULT_IMPLS = ("PC host", "PC-K1", "PC-K4", "PC-K8",
                 "PC-K4 nodonate", "PC-K4 pallas", "PC-K4 guarded",
                 "PC-adaptive", "PC-K4 megapass", "PC-K4 alternating",
                 "Lock", "RW Lock", "FC")

ROUNDS_CAP = 8


def _random_tree(rng, n):
    """Random spanning tree edges on [0, n)."""
    perm = rng.permutation(n)
    return [(int(perm[i]), int(perm[rng.integers(0, i)]))
            for i in range(1, n)]


def _device_graph(n_vertices, edge_capacity, *, n_shards, use_pallas=False,
                  donate=True, guard=None, placement=None):
    return DeviceGraph(n_vertices, edge_capacity=edge_capacity,
                       c_max=C_MAX, n_shards=n_shards,
                       use_pallas=use_pallas, donate=donate, guard=guard,
                       placement=placement)


def _make_impl(name, n_vertices, edge_capacity):
    """Returns (graph, execute) for one benchmark cell."""
    if name == "PC host":
        g = DynamicGraph(n_vertices)
        return g, batched_read_optimized(g).execute
    if name == "PC-adaptive":
        # adaptive tier routing (DESIGN.md §14): host DynamicGraph vs the
        # device-resident graph, routed per pass by the online cost model
        eng = adaptive_read_engine(
            _device_graph(n_vertices, edge_capacity, n_shards=4),
            DynamicGraph(n_vertices), structure="graph")
        return eng.adaptive_ds, eng.execute
    if name.startswith("PC-K"):
        key = name.split()
        K = int(key[0][len("PC-K"):])
        flavor = key[1] if len(key) > 1 else ""
        if flavor in ("megapass", "alternating"):
            # §17 fused megapass pair (ISSUE 9); the conservative
            # whole-megapass occupancy guard counts every insert lane of
            # the backlog as outstanding until its fetch resolves, so
            # give the edge buffer one megapass worth of headroom
            from repro.core.read_opt import MegapassCombiner
            g = _device_graph(n_vertices,
                              edge_capacity + ROUNDS_CAP * C_MAX,
                              n_shards=K)
            return g, MegapassCombiner(g, rounds_cap=ROUNDS_CAP,
                                       use_megapass=flavor == "megapass")
        placement = None
        if flavor == "mesh":
            # DESIGN.md §18: the connectivity rebuild's scatter-min
            # fixpoint runs with the edge list partitioned across the
            # combining mesh, per-iteration label merge via lax.pmin
            from repro.core.placement import MeshPlacement
            from repro.launch.mesh import make_combining_mesh

            placement = MeshPlacement(make_combining_mesh(K))
        g = _device_graph(n_vertices, edge_capacity, n_shards=K,
                          use_pallas=flavor == "pallas",
                          donate=flavor != "nodonate",
                          placement=placement,
                          # fault-free guarded twin (DESIGN.md §15):
                          # snapshot per pass, no fault plan attached
                          guard=True if flavor == "guarded" else None)
        return g, batched_read_optimized(g).execute
    g = DynamicGraph(n_vertices)
    if name == "Lock":
        return g, LockDS(g).execute
    if name == "RW Lock":
        return g, RWLockDS(g, g.read_only).execute
    if name == "FC":
        return g, flat_combining(g).execute
    raise ValueError(f"unknown impl {name!r}")


def bench_graph(n_vertices=1000, workloads=("tree", "forest"),
                read_pcts=(50, 90, 100), threads=(1, 2, 4, 8),
                ops=200, seed=0, impls=DEFAULT_IMPLS, repeats=5):
    results = []
    for wl in workloads:
        rng = np.random.default_rng(seed)
        if wl == "tree":
            trees = [_random_tree(rng, n_vertices)]
        else:
            trees = [_random_tree(rng, n_vertices) for _ in range(10)]
        # distinct tree edges bound the live set; the host guard is
        # conservative (live + batch ≤ capacity), so add c_max headroom
        distinct = {(min(u, v), max(u, v)) for t in trees for (u, v) in t}
        edge_capacity = len(distinct) + 2 * C_MAX

        def prepopulate(g):
            r = np.random.default_rng(seed + 1)
            batch = [e for t in trees for e in t if r.random() < 0.5]
            if hasattr(g, "insert_batch"):
                g.insert_batch(batch)
            else:
                for (u, v) in batch:
                    g.insert(u, v)
            return g

        def warmup(g, ex, e0, max_p):
            """Exercise every op path (insert/delete pass, full rebuild,
            fast-path merge, fused AND lean reads, every read-batch width
            the combiner can produce with ≤ max_p threads) BEFORE the
            timed section, restoring the edge set — jit compile time must
            not pollute the rows."""
            if ex("insert", e0):
                ex("connected", (0, 1))
                ex("delete", e0)
            else:
                ex("delete", e0)
                ex("connected", (0, 1))
                ex("insert", e0)
            # read-batch widths 1..max_p (the first is the refresh path,
            # the rest hit the labels-current lean path)
            for k in range(1, max_p + 1):
                g.read_batch(["connected"] * k, [(0, 1)] * k)

        for c in read_pcts:
            for P in threads:
                for name in impls:
                    g, ex = _make_impl(name, n_vertices, edge_capacity)
                    eng = None
                    if not callable(ex):    # MegapassCombiner rows
                        eng, ex = ex, ex.execute
                    prepopulate(g)
                    warmup(g, ex, trees[0][0], P)
                    td = getattr(g, "tier_decisions", None)
                    if td is not None:  # count the timed window only
                        for k in td:
                            td[k] = 0

                    def _draw(r):
                        p = r.random() * 100
                        if p < c:
                            return "connected", (
                                int(r.integers(0, n_vertices)),
                                int(r.integers(0, n_vertices)))
                        t = trees[int(r.integers(0, len(trees)))]
                        e = t[int(r.integers(0, len(t)))]
                        return ("insert" if p < c + (100 - c) / 2
                                else "delete"), e

                    if eng is not None:
                        # async session: publish, drain at the end
                        def body(tid, eng=eng):
                            r = np.random.default_rng(1000 + tid)
                            futs = [eng.submit(*_draw(r))
                                    for _ in range(ops)]
                            for f in futs:
                                f.result()
                    else:
                        def body(tid, ex=ex):
                            r = np.random.default_rng(1000 + tid)
                            for _ in range(ops):
                                ex(*_draw(r))

                    row = measure(P, ops, body, repeats=repeats)
                    row.update({"workload": wl, "read_pct": c,
                                "threads": P, "impl": name})
                    if name.endswith(" mesh"):
                        from repro.launch.mesh import make_combining_mesh

                        k = int(name.split()[0][len("PC-K"):])
                        row["device_count"] = int(
                            make_combining_mesh(k).shape["shard"])
                    if td is not None:
                        row["tier_decisions"] = dict(td)
                    extra = ""
                    if eng is not None:
                        row["rounds_per_dispatch"] = round(
                            eng.rounds_per_dispatch, 2)
                        extra = f" r/d {row['rounds_per_dispatch']:.2f}"
                        eng.close()
                    results.append(row)
                    print(f"[graph] {wl} c={c}% P={P} {name:16s}"
                          f" {row['ops_per_s']:9.0f} ops/s "
                          f"(iqr {row['iqr']:.0f}){extra}")
    save("bench_graph", results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1000)
    ap.add_argument("--ops", type=int, default=200)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--reads", type=int, nargs="+", default=[50, 90, 100])
    ap.add_argument("--workloads", nargs="+", default=["tree", "forest"])
    ap.add_argument("--impls", nargs="+", default=list(DEFAULT_IMPLS))
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per row (median + IQR reported)")
    a = ap.parse_args(argv)
    bench_graph(n_vertices=a.vertices, ops=a.ops, threads=tuple(a.threads),
                read_pcts=tuple(a.reads), workloads=tuple(a.workloads),
                impls=tuple(a.impls), repeats=a.repeats)


if __name__ == "__main__":
    main()
