"""Paper Fig. 1 — concurrent dynamic-graph throughput.

Workloads (paper §5.1): *Tree* (one random spanning tree, half its edges
prepopulated) and *Forest* (10 random trees); each thread applies
AreConnected with probability c% and Insert/Delete of a tree edge with
(100-c)/2% each, c ∈ {50, 80, 100}.

Implementations: PC (batched read combining — §3.3 TPU-native variant),
Lock (global mutex), RW Lock, FC (flat combining).  The paper's claim:
PC > {Lock, RW Lock, FC} and the gap grows with both thread count and
read share, because the combined read batch costs ONE vectorized device
call regardless of batch size.
"""
from __future__ import annotations

import argparse
import numpy as np

from repro.core.dynamic_graph import DynamicGraph
from repro.core.flat_combining import flat_combining
from repro.core.locks import LockDS, RWLockDS
from repro.core.read_opt import batched_read_optimized

from .common import save, throughput


def _random_tree(rng, n):
    """Random spanning tree edges on [0, n)."""
    perm = rng.permutation(n)
    return [(int(perm[i]), int(perm[rng.integers(0, i)]))
            for i in range(1, n)]


def bench_graph(n_vertices=1000, workloads=("tree", "forest"),
                read_pcts=(50, 80, 100), threads=(1, 2, 4, 8),
                ops=200, seed=0):
    results = []
    for wl in workloads:
        rng = np.random.default_rng(seed)
        if wl == "tree":
            trees = [_random_tree(rng, n_vertices)]
        else:
            trees = [_random_tree(rng, n_vertices) for _ in range(10)]

        def fresh_graph():
            g = DynamicGraph(n_vertices)
            r = np.random.default_rng(seed + 1)
            for t in trees:
                for (u, v) in t:
                    if r.random() < 0.5:
                        g.insert(u, v)
            return g

        for c in read_pcts:
            for P in threads:
                impls = {
                    "PC": lambda g: batched_read_optimized(g).execute,
                    "Lock": lambda g: LockDS(g).execute,
                    "RW Lock": lambda g: RWLockDS(g, g.read_only).execute,
                    "FC": lambda g: flat_combining(g).execute,
                }
                for name, make in impls.items():
                    g = fresh_graph()
                    ex = make(g)

                    def body(tid, ex=ex):
                        r = np.random.default_rng(1000 + tid)
                        for _ in range(ops):
                            p = r.random() * 100
                            if p < c:
                                u = int(r.integers(0, n_vertices))
                                v = int(r.integers(0, n_vertices))
                                ex("connected", (u, v))
                            else:
                                t = trees[int(r.integers(0, len(trees)))]
                                e = t[int(r.integers(0, len(t)))]
                                if p < c + (100 - c) / 2:
                                    ex("insert", e)
                                else:
                                    ex("delete", e)

                    tput = throughput(P, ops, body)
                    results.append({"workload": wl, "read_pct": c,
                                    "threads": P, "impl": name,
                                    "ops_per_s": round(tput, 1)})
                    print(f"[graph] {wl} c={c}% P={P} {name:8s}"
                          f" {tput:9.0f} ops/s")
    save("bench_graph", results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1000)
    ap.add_argument("--ops", type=int, default=200)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--reads", type=int, nargs="+", default=[50, 80, 100])
    a = ap.parse_args(argv)
    bench_graph(n_vertices=a.vertices, ops=a.ops, threads=tuple(a.threads),
                read_pcts=tuple(a.reads))


if __name__ == "__main__":
    main()
