"""Technique-in-framework: PC serving scheduler vs serial dispatch.

The production claim (DESIGN.md §3): under concurrent sessions, the
parallel-combining scheduler turns N per-request device dispatches into
~N/batch combined dispatches, with the batched-PQ deadline ordering.
Measures requests/s and device-step counts for the serial baseline, the
async PC scheduler with blocking submits ("pc"), the fully non-blocking
``submit_async`` client path ("pc-async"), and the zero-copy ablation
("pc-nodonate": the deadline PQ copies its heap buffers every combining
pass — EXPERIMENTS §Ablations) over the reduced qwen2 model.  The
"pc-pallas" mode (PQ through the shard-grid kernels, DESIGN.md §10) is
opt-in via ``schedulers=``, not in the default run — Pallas interpret
mode on a CPU backend is too slow for a benchmark row.

``--workload <structure>`` serves ANY registered batched structure
(``repro.core.substrate``, DESIGN.md §16 — graph, map, pq, sketch,
unionfind, ...) through the same schedulers via the generic
``StructureExecutor`` with ``--read-pct`` read share; rows land in
bench_serving_<structure>.json.
"""
from __future__ import annotations

import argparse

from repro.core import substrate
from repro.launch.serve import run_serving

from ._timing import median_iqr
from .common import save


def bench_serving(arch="qwen2_0_5b", session_counts=(1, 2, 4, 8),
                  requests=3, tokens=6, max_batch=8,
                  schedulers=("serial", "pc", "pc-async", "pc-nodonate"),
                  workload="decode", read_pct=90, repeats=5):
    """Each cell runs ``repeats`` times after one warmup run; the row is
    the median-``req_per_s`` sample with the IQR attached (the
    ``benchmarks._timing`` discipline — ``run_serving`` owns its own wall
    clock, so the median is taken over whole serving runs)."""
    results = []
    for sched in schedulers:
        for s in session_counts:
            def cell():
                return run_serving(arch, sessions=s,
                                   requests_per_session=requests,
                                   n_tokens=tokens, max_batch=max_batch,
                                   scheduler=sched, seed=42,
                                   workload=workload, read_pct=read_pct)

            cell()                                    # warmup
            samples = sorted((cell() for _ in range(repeats)),
                             key=lambda st: st["req_per_s"])
            # lower-middle sample: with an even count the upper-middle
            # would systematically report the better run as "median"
            stats = samples[(len(samples) - 1) // 2]
            spread = median_iqr(st["req_per_s"] for st in samples)
            stats["iqr"] = round(spread["iqr"], 2)
            stats["sessions"] = s
            results.append(stats)
            print(f"[serving] {workload} {sched:8s} sessions={s}: "
                  f"{stats['req_per_s']:6.2f} req/s "
                  f"(iqr {stats['iqr']}), "
                  f"{stats['device_steps']:4d} device steps, "
                  f"mean batch {stats['mean_batch']}")
    name = "bench_serving" if workload == "decode" \
        else f"bench_serving_{workload}"
    save(name, results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--tokens", type=int, default=6)
    ap.add_argument("--workload",
                    choices=["decode"] + substrate.names(),
                    default="decode")
    ap.add_argument("--read-pct", type=int, default=90)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per cell (median + IQR reported)")
    a = ap.parse_args(argv)
    bench_serving(session_counts=tuple(a.sessions), tokens=a.tokens,
                  workload=a.workload, read_pct=a.read_pct,
                  requests=a.requests, repeats=a.repeats)


if __name__ == "__main__":
    main()
