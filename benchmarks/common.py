"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")


def save(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    json.dump(payload, open(path, "w"), indent=1)
    return path


def run_threads(n: int, body: Callable[[int], None]) -> float:
    """Run ``body(tid)`` on n threads; returns wall seconds."""
    ts = [threading.Thread(target=body, args=(i,)) for i in range(n)]
    t0 = time.time()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return time.time() - t0


def throughput(n_threads: int, ops_per_thread: int,
               body: Callable[[int], None]) -> float:
    """ops/second across the thread group."""
    wall = run_threads(n_threads, body)
    return n_threads * ops_per_thread / wall
