"""Counting/top-k sketch throughput — the first registry-proven workload
(DESIGN.md §16), enrolled through its ``StructureSpec.bench`` row.

Workload: prepopulate S random key→count pairs; each thread issues reads
with probability c% — an even mix of ``count`` (known key), ``total``,
``distinct`` and ``topk`` — and ``add`` updates (70% revisiting a known
hot key, else a fresh one) otherwise.  Increments commute, so the fused
update pass is the paper's best case: the combiner nets a whole batch to
one scatter-add per shard.

Implementations:

* ``FC host`` — flat combining over the sequential sketch
  (``core/seq_sketch.py``): the host baseline.
* ``Lock`` — global mutex over the same host sketch (calibration row).
* ``PC-K{1,4}`` — ``batched_read_optimized`` over the K-sharded
  device-resident ``ShardedSketch`` (hash routed): fused donated add
  passes, one read program per combined read batch, one blocking fetch.
* ``PC-K4 nodonate`` / ``PC-K4 pallas`` — ablation twins (copy-per-pass
  dispatch; the scatter-add through the ``grid=(K,)`` Pallas kernel,
  interpret mode off-TPU).
* ``PC-K4 guarded`` — fault-free transactional-guard twin (DESIGN.md
  §15): snapshot per pass, no plan.
* ``PC-adaptive`` — tier routing by the online cost model (§14).

Every row reports median-of-N with IQR via ``benchmarks._timing.measure``;
rows are keyed (impl, read_pct, threads) for the CI regression gate
(``check_regression.py --bench sketch``).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.batched_sketch import ShardedSketch
from repro.core.locks import LockDS
from repro.core.pc_sketch import fc_sketch, pc_adaptive_sketch, pc_sketch
from repro.core.seq_sketch import SequentialSketch

from ._timing import measure
from .bench_pq import shard_capacity
from .common import save

C_MAX = 16
KEY_RANGE = (0.0, 1000.0)

DEFAULT_IMPLS = ("FC host", "Lock", "PC-K1", "PC-K4", "PC-K4 nodonate",
                 "PC-K4 pallas", "PC-K4 guarded", "PC-adaptive")


def _items(rng, n_keys):
    """n_keys distinct f32 keys from KEY_RANGE with integer counts."""
    grid = np.linspace(KEY_RANGE[0], KEY_RANGE[1], 8 * n_keys,
                       endpoint=False).astype(np.float32)
    keys = rng.choice(grid, n_keys, replace=False)
    return [(float(k), float(int(rng.integers(1, 10)))) for k in keys]


def _make_impl(name, items, capacity):
    """Returns the engine/wrapper object; call ``.execute`` on it."""
    if name == "FC host":
        return fc_sketch(items)
    if name == "Lock":
        return LockDS(SequentialSketch(items))
    if name == "PC-adaptive":
        return pc_adaptive_sketch(shard_capacity(capacity, 4, c_max=C_MAX),
                                  c_max=C_MAX, n_shards=4, items=items)
    if name.startswith("PC-K"):
        parts = name.split()
        K = int(parts[0][len("PC-K"):])
        flavor = parts[1] if len(parts) > 1 else ""
        # hash routing is i.i.d. per shard: binomial-tail sizing applies
        s = ShardedSketch(shard_capacity(capacity, K, c_max=C_MAX),
                          c_max=C_MAX, n_shards=K, items=items,
                          use_pallas=flavor == "pallas",
                          donate=flavor != "nodonate",
                          guard=True if flavor == "guarded" else None)
        return pc_sketch(s)
    raise ValueError(f"unknown impl {name!r}")


def bench_sketch(n_keys=2000, read_pcts=(50, 90, 100),
                 threads=(1, 2, 4, 8), ops=200, seed=0,
                 impls=DEFAULT_IMPLS, repeats=5):
    results = []
    rng = np.random.default_rng(seed)
    items = _items(rng, n_keys)
    known = np.asarray([k for k, _ in items], np.float32)

    def warmup(ex):
        """Exercise every op path (fused add pass, every read kind) so
        jit compile time stays out of the timed rows."""
        ex("add", (KEY_RANGE[1] - 1.0, 1.0))
        ex("count", KEY_RANGE[1] - 1.0)
        ex("total", None)
        ex("distinct", None)
        ex("topk", 4)

    for c in read_pcts:
        for P in threads:
            for name in impls:
                # bound the live key set: warmup + repeats timed runs add
                # at most (repeats+2)·P·ops fresh keys on top of S
                cap = n_keys + (repeats + 2) * P * ops + 2
                eng = _make_impl(name, items, cap)
                ex = eng.execute
                warmup(ex)
                td = getattr(eng, "tier_decisions", None)
                if td is not None:      # count the timed window only
                    for k in td:
                        td[k] = 0

                def body(tid, ex=ex):
                    r = np.random.default_rng(1000 + tid)
                    for _ in range(ops):
                        p = r.random() * 100
                        if p < c:
                            q = int(r.integers(0, 4))
                            if q == 0:
                                ex("count",
                                   float(known[r.integers(len(known))]))
                            elif q == 1:
                                ex("total", None)
                            elif q == 2:
                                ex("distinct", None)
                            else:
                                ex("topk", int(r.integers(1, 8)))
                        else:
                            if r.random() < 0.7:
                                key = float(known[r.integers(len(known))])
                            else:
                                key = float(np.float32(
                                    r.uniform(*KEY_RANGE)))
                            ex("add", (key, float(int(r.integers(1, 10)))))

                row = measure(P, ops, body, repeats=repeats)
                row.update({"read_pct": c, "threads": P, "impl": name,
                            "n_keys": n_keys})
                if td is not None:
                    row["tier_decisions"] = dict(td)
                results.append(row)
                print(f"[sketch] c={c}% P={P} {name:16s}"
                      f" {row['ops_per_s']:9.0f} ops/s "
                      f"(iqr {row['iqr']:.0f})")
    save("bench_sketch", results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=2000)
    ap.add_argument("--ops", type=int, default=200)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--reads", type=int, nargs="+", default=[50, 90, 100])
    ap.add_argument("--impls", nargs="+", default=list(DEFAULT_IMPLS))
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per row (median + IQR reported)")
    a = ap.parse_args(argv)
    bench_sketch(n_keys=a.keys, ops=a.ops, threads=tuple(a.threads),
                 read_pcts=tuple(a.reads), impls=tuple(a.impls),
                 repeats=a.repeats)


if __name__ == "__main__":
    main()
