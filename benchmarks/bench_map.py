"""Batched ordered map throughput — the third workload (DESIGN.md §13).

Workload: prepopulate S random key→value pairs from a fixed key range;
each thread issues reads with probability c% — an even mix of ``lookup``,
``range_count``, ``range_sum`` and ``kth_smallest`` — and updates with
(100-c)/3% each of ``insert`` (fresh key), ``assign`` and ``delete``
(known key).  The read-fraction sweep c ∈ {50, 90, 100} probes the
paper's §5.1 read-dominated setting, where the §3.3 transform answers the
whole combined read list with ONE vectorized device program.

Implementations:

* ``FC host`` — flat combining over the sequential sorted map
  (``core/seq_map.py``): the host baseline the device tier must beat on
  the read-dominated mix (EXPERIMENTS §Map).
* ``Lock`` — global mutex over the same host map (calibration row).
* ``PC-K{1,4,8}`` — ``batched_read_optimized`` over the K-sharded
  device-resident ``ShardedMap`` (key-range routed): fused mixed-op
  update passes (net-effect sort-merge), one read program per combined
  read batch, one blocking fetch per pass.
* ``PC-K4 nodonate`` / ``PC-K4 pallas`` — ablation twins (EXPERIMENTS
  §Ablations): copy-per-pass dispatch, and the merge-compact through the
  ``grid=(K,)`` Pallas kernel (interpret mode off-TPU).
* ``PC-K4 guarded`` — the fault-free transactional-guard twin
  (DESIGN.md §15; EXPERIMENTS §Robustness): snapshot per pass, no plan.
* ``PC-K{4,8} mesh`` — the DESIGN.md §18 placement twins: same
  per-shard capacity as the stacked ``PC-K{K}`` row, the K shards
  placed across D real devices (``make_combining_mesh``) with fused
  passes under shard_map; rows carry ``device_count`` and are
  auto-appended only when jax sees >1 device.
* ``PC-K4 megapass`` / ``PC-K4 alternating`` — the §17 fused megapass
  pair (ISSUE 9): async-session clients publish their op stream to a
  ``MegapassCombiner`` and drain futures at the end of the run; the
  megapass row lowers up to ``rounds_cap`` mixed update+read rounds
  onto ONE donated scan dispatch, the alternating twin sends the SAME
  rounds as one device program each — the pair isolates exactly the
  dispatch-fusion effect, and both report ``rounds_per_dispatch``.

Every row reports median-of-N (default 5) with IQR via
``benchmarks._timing.measure``; rows are keyed (impl, read_pct, threads)
for the CI regression gate (``check_regression.py --bench map``).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.batched_map import ShardedMap
from repro.core.locks import LockDS
from repro.core.pc_map import fc_map, pc_adaptive_map, pc_map
from repro.core.seq_map import SequentialSortedMap

from ._timing import measure
from .bench_pq import shard_capacity
from .common import save

C_MAX = 16
KEY_RANGE = (0.0, 1000.0)

DEFAULT_IMPLS = ("FC host", "Lock", "PC-K1", "PC-K4", "PC-K8",
                 "PC-K4 nodonate", "PC-K4 pallas", "PC-K4 guarded",
                 "PC-adaptive", "PC-K4 megapass", "PC-K4 alternating")

ROUNDS_CAP = 8


def _draw_op(r, c, known, n_keys):
    """One op from the benchmark mix: reads with probability c%, the
    same distribution for every implementation row."""
    p = r.random() * 100
    if p < c:
        q = int(r.integers(0, 4))
        if q == 0:
            return "lookup", float(known[r.integers(len(known))])
        if q == 1:
            return "kth_smallest", int(r.integers(1, n_keys))
        lo = float(np.float32(r.uniform(0, KEY_RANGE[1] - 50)))
        return ("range_count" if q == 2 else "range_sum"), (lo, lo + 50.0)
    q = int(r.integers(0, 3))
    if q == 0:
        return "insert", (float(np.float32(r.uniform(*KEY_RANGE))),
                          float(np.float32(r.uniform(0, 10))))
    if q == 1:
        return "assign", (float(known[r.integers(len(known))]),
                          float(np.float32(r.uniform(0, 10))))
    return "delete", float(known[r.integers(len(known))])


def _items(rng, n_keys):
    """n_keys distinct f32 keys from KEY_RANGE with random values."""
    grid = np.linspace(KEY_RANGE[0], KEY_RANGE[1], 8 * n_keys,
                       endpoint=False).astype(np.float32)
    keys = rng.choice(grid, n_keys, replace=False)
    return [(float(k), float(np.float32(rng.uniform(0, 10))))
            for k in keys]


def _make_impl(name, items, capacity):
    """Returns the engine/wrapper object; call ``.execute`` on it."""
    if name == "FC host":
        return fc_map(items)
    if name == "Lock":
        return LockDS(SequentialSortedMap(items))
    if name == "PC-adaptive":
        # adaptive tier routing (DESIGN.md §14): host mirror vs K-sharded
        # device map, routed per combining pass by the online cost model
        return pc_adaptive_map(shard_capacity(capacity, 4, c_max=C_MAX),
                               c_max=C_MAX, n_shards=4,
                               key_range=KEY_RANGE, items=items)
    if name.startswith("PC-K"):
        parts = name.split()
        K = int(parts[0][len("PC-K"):])
        flavor = parts[1] if len(parts) > 1 else ""
        if flavor in ("megapass", "alternating"):
            # §17 fused megapass pair (ISSUE 9): same async drain loop,
            # one fused scan (megapass) vs one program per round
            # (alternating) — see module docstring
            from repro.core.pc_map import pc_megapass_map
            return pc_megapass_map(
                shard_capacity(capacity, K, c_max=C_MAX), c_max=C_MAX,
                n_shards=K, key_range=KEY_RANGE, items=items,
                rounds_cap=ROUNDS_CAP,
                use_megapass=flavor == "megapass")
        placement = None
        if flavor == "mesh":
            # DESIGN.md §18 placement twin: SAME per-shard capacity as
            # the stacked PC-K{K} row (equal total capacity), K shards
            # across D devices, fused passes under shard_map
            from repro.core.placement import MeshPlacement
            from repro.launch.mesh import make_combining_mesh

            placement = MeshPlacement(make_combining_mesh(K))
        # key-range routing of near-uniform keys is i.i.d. per shard, so
        # the binomial-tail sizing of bench_pq.shard_capacity applies
        m = ShardedMap(shard_capacity(capacity, K, c_max=C_MAX),
                       c_max=C_MAX, n_shards=K, key_range=KEY_RANGE,
                       items=items, use_pallas=flavor == "pallas",
                       donate=flavor != "nodonate",
                       placement=placement,
                       # fault-free guarded twin (DESIGN.md §15): every
                       # pass pays the snapshot, no fault plan attached
                       guard=True if flavor == "guarded" else None)
        return pc_map(m)
    raise ValueError(f"unknown impl {name!r}")


def bench_map(n_keys=2000, read_pcts=(50, 90, 100), threads=(1, 2, 4, 8),
              ops=200, seed=0, impls=DEFAULT_IMPLS, repeats=5):
    import jax

    results = []
    rng = np.random.default_rng(seed)
    items = _items(rng, n_keys)
    known = np.asarray([k for k, _ in items], np.float32)
    # mesh twins only differ from stacked when the combining mesh lands
    # on >1 device — auto-append so single-device smoke runs keep the
    # exact historical row set (pass "PC-K{K} mesh" in impls to force)
    if impls == DEFAULT_IMPLS and jax.device_count() > 1:
        impls = tuple(impls) + ("PC-K4 mesh", "PC-K8 mesh")

    def _mesh_devices(name):
        from repro.launch.mesh import make_combining_mesh

        k = int(name.split()[0][len("PC-K"):])
        return int(make_combining_mesh(k).shape["shard"])

    def warmup(ex):
        """Exercise every op path (fused update pass, every read kind,
        both the update+read and read-only combiner passes) before the
        timed section — jit compile time must not pollute the rows."""
        ex("insert", (KEY_RANGE[1] - 1.0, 0.0))
        ex("lookup", KEY_RANGE[1] - 1.0)
        ex("range_count", (0.0, 10.0))
        ex("range_sum", (0.0, 10.0))
        ex("kth_smallest", 1)
        ex("assign", (KEY_RANGE[1] - 1.0, 1.0))
        ex("delete", KEY_RANGE[1] - 1.0)

    for c in read_pcts:
        for P in threads:
            for name in impls:
                # bound the live set: warmup + repeats timed runs insert
                # at most (repeats+2)·P·ops fresh keys on top of the S
                # initial ones (+ the op-path warmup)
                cap = n_keys + (repeats + 2) * P * ops + 2
                eng = _make_impl(name, items, cap)
                ex = eng.execute
                warmup(ex)
                td = getattr(eng, "tier_decisions", None)
                if td is not None:      # count the timed window only
                    for k in td:
                        td[k] = 0

                submit = getattr(eng, "submit", None)
                if submit is not None:
                    # megapass/alternating rows: async-session clients
                    # publish the op stream and drain futures at the end
                    # (the AsyncRoundsPQ client model of bench_pq)
                    def body(tid, submit=submit):
                        r = np.random.default_rng(1000 + tid)
                        futs = [submit(*_draw_op(r, c, known, n_keys))
                                for _ in range(ops)]
                        for f in futs:
                            f.result()
                else:
                    def body(tid, ex=ex):
                        r = np.random.default_rng(1000 + tid)
                        for _ in range(ops):
                            ex(*_draw_op(r, c, known, n_keys))

                row = measure(P, ops, body, repeats=repeats)
                row.update({"read_pct": c, "threads": P, "impl": name,
                            "n_keys": n_keys})
                if name.endswith(" mesh"):
                    # only mesh rows carry the field: every pre-existing
                    # row keeps its exact check_regression key
                    row["device_count"] = _mesh_devices(name)
                if td is not None:
                    row["tier_decisions"] = dict(td)
                rpd = getattr(eng, "rounds_per_dispatch", None)
                if rpd is not None:
                    row["rounds_per_dispatch"] = round(rpd, 2)
                results.append(row)
                extra = (f" r/d {row['rounds_per_dispatch']:.2f}"
                         if "rounds_per_dispatch" in row else "")
                print(f"[map] c={c}% P={P} {name:16s}"
                      f" {row['ops_per_s']:9.0f} ops/s "
                      f"(iqr {row['iqr']:.0f}){extra}")
                if submit is not None:
                    eng.close()
    save("bench_map", results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=2000)
    ap.add_argument("--ops", type=int, default=200)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--reads", type=int, nargs="+", default=[50, 90, 100])
    ap.add_argument("--impls", nargs="+", default=list(DEFAULT_IMPLS))
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per row (median + IQR reported)")
    a = ap.parse_args(argv)
    bench_map(n_keys=a.keys, ops=a.ops, threads=tuple(a.threads),
              read_pcts=tuple(a.reads), impls=tuple(a.impls),
              repeats=a.repeats)


if __name__ == "__main__":
    main()
