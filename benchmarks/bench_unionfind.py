"""Batched union-find throughput — the second registry-proven workload
(DESIGN.md §16), enrolled through its ``StructureSpec.bench`` row.

Workload: n vertices, initially singleton; each thread issues reads with
probability c% — an even mix of ``find``, ``connected`` and
``components`` — and ``union`` updates otherwise (50% chain edges, the
long-merge-path stress case for the contracted fixpoint, else random
links).  Unions are idempotent on state, so the fused merge pass nets a
combined batch to one contracted scatter-min fixpoint per pass.

Implementations:

* ``FC host`` — flat combining over the sequential union-find
  (``core/seq_union_find.py``): the host baseline.
* ``Lock`` — global mutex over the same host structure (calibration).
* ``PC`` — ``batched_read_optimized`` over the device-resident
  ``BatchedUnionFind``: fused donated merge passes, one read program per
  combined read batch, one blocking fetch per pass.
* ``PC nodonate`` / ``PC pallas`` — ablation twins (copy-per-pass
  dispatch; the label fixpoint through the ``grid=(K,)`` Pallas kernel,
  interpret mode off-TPU).
* ``PC guarded`` — fault-free transactional-guard twin (DESIGN.md §15).
* ``PC-adaptive`` — tier routing by the online cost model (§14).

Every row reports median-of-N with IQR via ``benchmarks._timing.measure``;
rows are keyed (impl, read_pct, threads) for the CI regression gate
(``check_regression.py --bench unionfind``).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.locks import LockDS
from repro.core.pc_union_find import (fc_union_find,
                                      pc_adaptive_union_find,
                                      pc_batched_union_find)
from repro.core.seq_union_find import SequentialUnionFind

from ._timing import measure
from .common import save

C_MAX = 16

DEFAULT_IMPLS = ("FC host", "Lock", "PC", "PC nodonate", "PC pallas",
                 "PC guarded", "PC-adaptive")


def _make_impl(name, n):
    """Returns the engine/wrapper object; call ``.execute`` on it."""
    if name == "FC host":
        return fc_union_find(n)
    if name == "Lock":
        return LockDS(SequentialUnionFind(n))
    if name == "PC-adaptive":
        return pc_adaptive_union_find(n, c_max=C_MAX)
    if name == "PC" or name.startswith("PC "):
        flavor = name[3:]
        return pc_batched_union_find(
            n, c_max=C_MAX,
            n_shards=4 if flavor == "pallas" else 1,
            use_pallas=flavor == "pallas",
            donate=flavor != "nodonate",
            guard=True if flavor == "guarded" else None)
    raise ValueError(f"unknown impl {name!r}")


def bench_unionfind(n=1024, read_pcts=(50, 90, 100), threads=(1, 2, 4, 8),
                    ops=200, seed=0, impls=DEFAULT_IMPLS, repeats=5):
    results = []

    def warmup(ex):
        """Exercise every op path before the timed section."""
        ex("union", (0, 1))
        ex("find", 0)
        ex("connected", (0, 2))
        ex("components", None)

    for c in read_pcts:
        for P in threads:
            for name in impls:
                eng = _make_impl(name, n)
                ex = eng.execute
                warmup(ex)
                td = getattr(eng, "tier_decisions", None)
                if td is not None:      # count the timed window only
                    for k in td:
                        td[k] = 0

                def body(tid, ex=ex):
                    r = np.random.default_rng(1000 + tid)
                    for _ in range(ops):
                        p = r.random() * 100
                        if p < c:
                            q = int(r.integers(0, 3))
                            if q == 0:
                                ex("find", int(r.integers(n)))
                            elif q == 1:
                                ex("connected", (int(r.integers(n)),
                                                 int(r.integers(n))))
                            else:
                                ex("components", None)
                        else:
                            u = int(r.integers(n))
                            v = ((u + 1) % n if r.random() < 0.5
                                 else int(r.integers(n)))
                            ex("union", (u, v))

                row = measure(P, ops, body, repeats=repeats)
                row.update({"read_pct": c, "threads": P, "impl": name,
                            "n": n})
                if td is not None:
                    row["tier_decisions"] = dict(td)
                results.append(row)
                print(f"[unionfind] c={c}% P={P} {name:16s}"
                      f" {row['ops_per_s']:9.0f} ops/s "
                      f"(iqr {row['iqr']:.0f})")
    save("bench_unionfind", results)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1024)
    ap.add_argument("--ops", type=int, default=200)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--reads", type=int, nargs="+", default=[50, 90, 100])
    ap.add_argument("--impls", nargs="+", default=list(DEFAULT_IMPLS))
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per row (median + IQR reported)")
    a = ap.parse_args(argv)
    bench_unionfind(n=a.vertices, ops=a.ops, threads=tuple(a.threads),
                    read_pcts=tuple(a.reads), impls=tuple(a.impls),
                    repeats=a.repeats)


if __name__ == "__main__":
    main()
