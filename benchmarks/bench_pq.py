"""Paper Fig. 2 — priority-queue throughput under contention.

Implementations (paper's rivals adapted per DESIGN.md §8.4):
  PC        — parallel combining over the §4 batched binary heap (ours)
  PC-K{K}   — parallel combining over the K-sharded batched heap
              (DESIGN.md §9); sharded vs single-heap at K ∈ {1, 4, 8}
  FC Binary — flat combining over the sequential Gonnet–Munro heap
  Lock      — global mutex over the sequential heap
  Lock SL   — global mutex over the skip-list PQ (fine-grained stand-in)

Ablation rows (EXPERIMENTS §Ablations; DESIGN.md §10, §12):
  PC-K{K} nodonate — same program, donation off: XLA copies the
              (K, capacity) heap buffers every combining pass
  PC-K{K} pallas   — phases 1/3/4 as shard-grid Pallas kernels
              (grid=(K,)); on a CPU backend these run in interpret mode
              (slow — enable with --ablate-pallas; on-by-default on TPU)
  PC-K{K} rounds   — the §12 fused multi-round path: async clients
              publish ops to an ``AsyncRoundsPQ`` combiner that packs up
              to R (--rounds-cap) combining rounds into ONE donated
              ``apply_rounds`` dispatch, with the host elimination
              pre-pass in front.  Threads issue their op stream
              non-blockingly and drain their extract futures at the end
              of the run (the async-session client model of the
              serving scheduler), so the row measures the amortized
              dispatch claim rather than per-op round-trip latency.
  PC-K4 guarded    — the transactional DispatchGuard (DESIGN.md §15)
              around every combining pass with NO fault plan attached:
              the fault-free snapshot overhead (EXPERIMENTS §Robustness,
              acceptance ≤10% vs the ungated PC-K4 row)
  PC-K{K} mesh     — the DESIGN.md §18 placement twin: SAME per-shard
              capacity (equal total capacity vs PC-K{K}), the K shards
              placed across D real devices via ``make_combining_mesh``,
              fused passes under shard_map with collective merges.
              Rows carry ``device_count`` (= D) and appear by default
              only when jax sees >1 device (``XLA_FLAGS=--xla_force_
              host_platform_device_count=N``); force with --ablate-mesh
  PC-K4 megapass / PC-K4 alternating — the §17 fused update+read
              megapass pair (ISSUE 9) on a MIXED workload (25% insert,
              25% extract_min, 50% peek_min): async-session clients
              publish to a ``MegapassCombiner``; the megapass row
              lowers up to R mixed rounds onto ONE donated scan
              dispatch, the alternating twin sends the SAME rounds one
              program each — both report ``rounds_per_dispatch``

Every row reports median-of-N (default 5) with IQR via
``benchmarks._timing.measure`` — single-shot rows swung 2–3× run-to-run
on the CI container (EXPERIMENTS §Ablations).

Workload (paper §5.2): prepopulate with S values from range R; each thread
alternates 50/50 Insert(random)/ExtractMin.

Two comparison tiers (DESIGN.md §8.1):
  * device tier (the transferable claim) — "Lock Device" serializes the
    SAME device-resident batched heap with one device dispatch per op;
    "PC" pays one dispatch per *combined batch*.  Both pay identical
    dispatch latency, so the ratio isolates exactly what the paper
    measures: combining amortizes synchronization+dispatch.
  * host-native tier (reference only) — pure-python heap/skip-list under
    Lock/FC.  CPython vs XLA-dispatch absolute speeds are incomparable;
    these rows calibrate the GIL ceiling, nothing more.
"""
from __future__ import annotations

import argparse
import math

import numpy as np

from repro.core.batched_pq import BatchedPriorityQueue
from repro.core.locks import LockDS
from repro.core.pc_pq import (AsyncRoundsPQ, fc_priority_queue,
                              pc_adaptive_priority_queue,
                              pc_megapass_priority_queue,
                              pc_priority_queue,
                              pc_sharded_priority_queue)
from repro.core.seq_pq import SequentialHeap
from repro.core.sharded_pq import ShardedBatchedPQ
from repro.core.skiplist_pq import SkipListPQ

from ._timing import measure
from .common import save

C_MAX = 16


def shard_capacity(n_keys: int, n_shards: int, c_max: int = C_MAX,
                   z: float = 6.0) -> int:
    """Per-shard heap capacity that survives hash-routing skew w.h.p.

    Hash routing drops each of the ≤ ``n_keys`` live keys into one of K
    shards i.i.d. uniformly, so a shard's occupancy is Binomial(n, 1/K).
    Size for mean + z·σ (normal tail of the binomial: z = 6 puts the
    per-shard overflow odds below 1e-9 — the wrapper's occupancy guard
    still refuses loudly in the astronomically unlikely tail) plus the
    worst case of one combined batch (c_max inserts all routed to the
    same shard) and the 1-indexed scratch slot.  Replaces the old
    ``2·S//K + 4096`` guess, which under-provisioned small K and wasted
    memory at large K.
    """
    n = max(int(n_keys), 1)
    p = 1.0 / n_shards
    sigma = math.sqrt(n * p * (1.0 - p))
    return int(math.ceil(n * p + z * sigma)) + c_max + 2


def bench_pq(sizes=(100_000,), threads=(1, 2, 4, 8), ops=300,
             value_range=2 ** 31 - 1, seed=0, shard_counts=(1, 4, 8),
             ablate_donation=True, ablate_pallas=None, ablate_rounds=True,
             ablate_megapass=True, ablate_mesh=None, rounds_cap=4,
             repeats=5):
    import jax

    if ablate_pallas is None:
        ablate_pallas = jax.default_backend() == "tpu"
    if ablate_mesh is None:
        # the mesh twin only differs from stacked when the combining
        # mesh lands on >1 device — auto-off on single-device hosts so
        # the tier-1 smoke rows stay byte-comparable across PRs
        ablate_mesh = jax.device_count() > 1
    results = []
    mesh_d = {}    # mesh row impl name -> its mesh's device count D
    for S in sizes:
        rng = np.random.default_rng(seed)
        init = rng.uniform(0, value_range, S).astype(np.float32)

        def make_impls(P):
            pq = BatchedPriorityQueue(2 * S + 4096, c_max=C_MAX,
                                      values=init)
            pq_serial = BatchedPriorityQueue(2 * S + 4096, c_max=C_MAX,
                                             values=init)
            heap = SequentialHeap()
            heap.a = [float("-inf")] + sorted(init.tolist())
            heap2 = SequentialHeap()
            heap2.a = [float("-inf")] + sorted(init.tolist())
            sl = SkipListPQ()
            for v in sorted(init.tolist()):
                sl.insert(v)
            impls = {
                "PC": pc_priority_queue(pq).execute,
                "Lock Device": LockDS(_DeviceHeapAdapter(pq_serial)).execute,
                "FC Binary": _fc(heap),
                "Lock": LockDS(heap2).execute,
                "Lock SL": LockDS(sl).execute,
            }
            # binomial-tail shard sizing: warmup + repeats timed runs
            # insert at most (repeats+1)·P·ops keys on top of the S
            # initial ones (+ the 2-op jit warmup)
            n_keys = S + (repeats + 1) * P * ops + 2
            # sharded vs single-heap (DESIGN.md §9): same PC engine, the
            # K-shard queue applies the combined batch as ONE device
            # program — K=1 isolates the sharding overhead vs plain "PC"
            rounds_impls = {}
            for K in shard_counts:
                cap_k = shard_capacity(n_keys, K)
                impls[f"PC-K{K}"] = pc_sharded_priority_queue(
                    cap_k, c_max=C_MAX, n_shards=K, values=init).execute
                if ablate_donation:
                    impls[f"PC-K{K} nodonate"] = pc_sharded_priority_queue(
                        cap_k, c_max=C_MAX, n_shards=K, values=init,
                        donate=False).execute
                if ablate_pallas:
                    impls[f"PC-K{K} pallas"] = pc_sharded_priority_queue(
                        cap_k, c_max=C_MAX, n_shards=K, values=init,
                        use_pallas=True).execute
                if K == 4:
                    # fault-free guarded twin (DESIGN.md §15): every pass
                    # runs through the transactional DispatchGuard with
                    # no fault plan attached — the row measures the pure
                    # snapshot overhead (EXPERIMENTS §Robustness, ≤10%)
                    impls["PC-K4 guarded"] = pc_sharded_priority_queue(
                        cap_k, c_max=C_MAX, n_shards=4, values=init,
                        guard=True).execute
                if ablate_mesh:
                    # mesh-placed twin (DESIGN.md §18): SAME per-shard
                    # capacity (equal total capacity vs the stacked
                    # PC-K{K} row), K shards over D real devices,
                    # collective merges via shard_map
                    from repro.core.placement import MeshPlacement
                    from repro.launch.mesh import make_combining_mesh

                    pl = MeshPlacement(make_combining_mesh(K))
                    impls[f"PC-K{K} mesh"] = pc_sharded_priority_queue(
                        cap_k, c_max=C_MAX, n_shards=K, values=init,
                        placement=pl).execute
                    mesh_d[f"PC-K{K} mesh"] = pl.n_devices
                if ablate_rounds:
                    # §12 fused multi-round path: async clients, up to
                    # rounds_cap combining rounds per donated dispatch
                    rounds_impls[f"PC-K{K} rounds"] = AsyncRoundsPQ(
                        ShardedBatchedPQ(cap_k, c_max=C_MAX, n_shards=K,
                                         values=init),
                        rounds_cap=rounds_cap)
            # adaptive tier routing (DESIGN.md §14): the online cost model
            # picks host / eliminate / device per combining pass
            adaptive = {"PC-adaptive": pc_adaptive_priority_queue(
                ShardedBatchedPQ(shard_capacity(n_keys, 4), c_max=C_MAX,
                                 n_shards=4, values=init))}
            impls["PC-adaptive"] = adaptive["PC-adaptive"].execute
            # §17 fused megapass pair (ISSUE 9): mixed update+read
            # workload — one fused scan vs one program per round
            mega_impls = {}
            if ablate_megapass:
                cap4 = shard_capacity(n_keys, 4)
                for mname, flag in (("PC-K4 megapass", True),
                                    ("PC-K4 alternating", False)):
                    mega_impls[mname] = pc_megapass_priority_queue(
                        cap4, c_max=C_MAX, n_shards=4, values=init,
                        rounds_cap=2 * rounds_cap, use_megapass=flag)
            return impls, rounds_impls, mega_impls, adaptive

        for P in threads:
            impls, rounds_impls, mega_impls, adaptive = make_impls(P)
            for name, ex in impls.items():
                # warm the jit caches outside the timed window
                ex("insert", 0.5)
                ex("extract_min")
                eng = adaptive.get(name)
                if eng is not None:
                    # complete the router's cold start outside the timed
                    # window too (one device dispatch mid-row would
                    # dominate these short windows), then count decisions
                    # from the timed window only
                    eng.prewarm()
                    for k in eng.tier_decisions:
                        eng.tier_decisions[k] = 0
                vals = rng.uniform(0, value_range, ops).astype(np.float32)

                def body(tid, ex=ex, vals=vals):
                    r = np.random.default_rng(tid)
                    for i in range(ops):
                        if r.integers(2) == 0:
                            ex("insert", float(vals[i]))
                        else:
                            ex("extract_min")

                row = measure(P, ops, body, repeats=repeats)
                row.update({"impl": name, "size": S, "threads": P})
                if name in mesh_d:
                    # only mesh rows carry the field: every pre-existing
                    # row keeps its exact check_regression key
                    row["device_count"] = mesh_d[name]
                if eng is not None:
                    row["tier_decisions"] = dict(eng.tier_decisions)
                results.append(row)
                print(f"[pq] S={S} P={P} {name:18s} "
                      f"{row['ops_per_s']:10.0f} ops/s "
                      f"(iqr {row['iqr']:.0f})")
            for name, eng in rounds_impls.items():
                eng.insert(0.5)
                eng.extract_async().result()      # jit warmup
                vals = rng.uniform(0, value_range, ops).astype(np.float32)

                def body(tid, eng=eng, vals=vals):
                    # async-session client: publish the op stream, drain
                    # the extract futures at the end of the run
                    r = np.random.default_rng(tid)
                    futs = []
                    for i in range(ops):
                        if r.integers(2) == 0:
                            eng.insert(float(vals[i]))
                        else:
                            futs.append(eng.extract_async())
                    for f in futs:
                        f.result()

                row = measure(P, ops, body, repeats=repeats)
                row.update({"impl": name, "size": S, "threads": P,
                            "rounds_cap": rounds_cap})
                results.append(row)
                print(f"[pq] S={S} P={P} {name:18s} "
                      f"{row['ops_per_s']:10.0f} ops/s "
                      f"(iqr {row['iqr']:.0f})")
                eng.close()
            for name, eng in mega_impls.items():
                # warm every fused program variant (update round, peek
                # round, both megapass shapes) outside the timed window
                eng.execute("insert", 0.5)
                eng.execute("peek_min")
                eng.execute("extract_min")
                vals = rng.uniform(0, value_range, ops).astype(np.float32)

                def body(tid, eng=eng, vals=vals):
                    # async session over the MIXED workload: 25% insert,
                    # 25% extract_min, 50% peek_min; drain at the end
                    r = np.random.default_rng(tid)
                    futs = []
                    for i in range(ops):
                        q = int(r.integers(0, 4))
                        if q == 0:
                            futs.append(eng.submit("insert",
                                                   float(vals[i])))
                        elif q == 1:
                            futs.append(eng.submit("extract_min"))
                        else:
                            futs.append(eng.submit("peek_min"))
                    for f in futs:
                        f.result()

                row = measure(P, ops, body, repeats=repeats)
                row.update({"impl": name, "size": S, "threads": P,
                            "rounds_cap": 2 * rounds_cap,
                            "peek_pct": 50,
                            "rounds_per_dispatch":
                                round(eng.rounds_per_dispatch, 2)})
                results.append(row)
                print(f"[pq] S={S} P={P} {name:18s} "
                      f"{row['ops_per_s']:10.0f} ops/s "
                      f"(iqr {row['iqr']:.0f}) "
                      f"r/d {row['rounds_per_dispatch']:.2f}")
                eng.close()
    save("bench_pq", results)
    return results


def _fc(heap):
    from repro.core.flat_combining import flat_combining
    return flat_combining(heap).execute


class _DeviceHeapAdapter:
    """One device dispatch per op — the fine-grained device baseline."""

    def __init__(self, pq: BatchedPriorityQueue):
        self.pq = pq

    def apply(self, method: str, input=None):
        if method == "insert":
            self.pq.apply(0, [input])
            return None
        return self.pq.apply(1, [])[0]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=100_000)
    ap.add_argument("--ops", type=int, default=300)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 4, 8],
                    help="shard counts K for the PC-K rows")
    ap.add_argument("--no-ablate-donation", action="store_true",
                    help="skip the 'PC-K{K} nodonate' ablation rows")
    ap.add_argument("--ablate-pallas", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="force the 'PC-K{K} pallas' ablation rows on/off "
                         "(default: on only on a TPU backend — interpret "
                         "mode on CPU is orders of magnitude slower)")
    ap.add_argument("--no-ablate-rounds", action="store_true",
                    help="skip the 'PC-K{K} rounds' fused multi-round rows")
    ap.add_argument("--no-ablate-megapass", action="store_true",
                    help="skip the 'PC-K4 megapass/alternating' mixed "
                         "update+read rows")
    ap.add_argument("--ablate-mesh", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="force the 'PC-K{K} mesh' device-mesh rows "
                         "on/off (default: on only when jax sees >1 "
                         "device — e.g. under XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=4)")
    ap.add_argument("--rounds-cap", type=int, default=4,
                    help="R cap for the fused multi-round rows")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed repeats per row (median + IQR reported)")
    a = ap.parse_args(argv)
    bench_pq(sizes=(a.size,), threads=tuple(a.threads), ops=a.ops,
             shard_counts=tuple(a.shards),
             ablate_donation=not a.no_ablate_donation,
             ablate_pallas=a.ablate_pallas,
             ablate_rounds=not a.no_ablate_rounds,
             ablate_megapass=not a.no_ablate_megapass,
             ablate_mesh=a.ablate_mesh,
             rounds_cap=a.rounds_cap, repeats=a.repeats)


if __name__ == "__main__":
    main()
