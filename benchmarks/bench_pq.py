"""Paper Fig. 2 — priority-queue throughput under contention.

Implementations (paper's rivals adapted per DESIGN.md §8.4):
  PC        — parallel combining over the §4 batched binary heap (ours)
  PC-K{K}   — parallel combining over the K-sharded batched heap
              (DESIGN.md §9); sharded vs single-heap at K ∈ {1, 4, 8}
  FC Binary — flat combining over the sequential Gonnet–Munro heap
  Lock      — global mutex over the sequential heap
  Lock SL   — global mutex over the skip-list PQ (fine-grained stand-in)

Workload (paper §5.2): prepopulate with S values from range R; each thread
alternates 50/50 Insert(random)/ExtractMin.

Two comparison tiers (DESIGN.md §8.1):
  * device tier (the transferable claim) — "Lock Device" serializes the
    SAME device-resident batched heap with one device dispatch per op;
    "PC" pays one dispatch per *combined batch*.  Both pay identical
    dispatch latency, so the ratio isolates exactly what the paper
    measures: combining amortizes synchronization+dispatch.
  * host-native tier (reference only) — pure-python heap/skip-list under
    Lock/FC.  CPython vs XLA-dispatch absolute speeds are incomparable;
    these rows calibrate the GIL ceiling, nothing more.
"""
from __future__ import annotations

import argparse
import numpy as np

from repro.core.batched_pq import BatchedPriorityQueue
from repro.core.locks import LockDS
from repro.core.pc_pq import (fc_priority_queue, pc_priority_queue,
                              pc_sharded_priority_queue)
from repro.core.seq_pq import SequentialHeap
from repro.core.skiplist_pq import SkipListPQ

from .common import save, throughput


def bench_pq(sizes=(100_000,), threads=(1, 2, 4, 8), ops=300,
             value_range=2 ** 31 - 1, seed=0, shard_counts=(1, 4, 8)):
    rng = np.random.default_rng(seed)
    results = []
    for S in sizes:
        init = rng.uniform(0, value_range, S).astype(np.float32)

        def make_impls():
            pq = BatchedPriorityQueue(2 * S + 4096, c_max=16,
                                      values=init)
            pq_serial = BatchedPriorityQueue(2 * S + 4096, c_max=16,
                                             values=init)
            heap = SequentialHeap()
            heap.a = [float("-inf")] + sorted(init.tolist())
            heap2 = SequentialHeap()
            heap2.a = [float("-inf")] + sorted(init.tolist())
            sl = SkipListPQ()
            for v in sorted(init.tolist()):
                sl.insert(v)
            impls = {
                "PC": pc_priority_queue(pq).execute,
                "Lock Device": LockDS(_DeviceHeapAdapter(pq_serial)).execute,
                "FC Binary": _fc(heap),
                "Lock": LockDS(heap2).execute,
                "Lock SL": LockDS(sl).execute,
            }
            # sharded vs single-heap (DESIGN.md §9): same PC engine, the
            # K-shard queue applies the combined batch as ONE vmapped
            # program — K=1 isolates the vmap overhead vs plain "PC"
            for K in shard_counts:
                impls[f"PC-K{K}"] = pc_sharded_priority_queue(
                    2 * S // max(K, 1) + 4096, c_max=16, n_shards=K,
                    values=init).execute
            return impls

        for P in threads:
            impls = make_impls()
            for name, ex in impls.items():
                # warm the jit caches outside the timed window
                ex("insert", 0.5)
                ex("extract_min")
                vals = rng.uniform(0, value_range, ops).astype(np.float32)

                def body(tid, ex=ex, vals=vals):
                    r = np.random.default_rng(tid)
                    for i in range(ops):
                        if r.integers(2) == 0:
                            ex("insert", float(vals[i]))
                        else:
                            ex("extract_min")

                tput = throughput(P, ops, body)
                results.append({"impl": name, "size": S, "threads": P,
                                "ops_per_s": round(tput, 1)})
                print(f"[pq] S={S} P={P} {name:10s} {tput:10.0f} ops/s")
    save("bench_pq", results)
    return results


def _fc(heap):
    from repro.core.flat_combining import flat_combining
    return flat_combining(heap).execute


class _DeviceHeapAdapter:
    """One device dispatch per op — the fine-grained device baseline."""

    def __init__(self, pq: BatchedPriorityQueue):
        self.pq = pq

    def apply(self, method: str, input=None):
        if method == "insert":
            self.pq.apply(0, [input])
            return None
        return self.pq.apply(1, [])[0]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=100_000)
    ap.add_argument("--ops", type=int, default=300)
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 4, 8],
                    help="shard counts K for the PC-K rows")
    a = ap.parse_args(argv)
    bench_pq(sizes=(a.size,), threads=tuple(a.threads), ops=a.ops,
             shard_counts=tuple(a.shards))


if __name__ == "__main__":
    main()
