"""Roofline analysis: measured kernel bandwidth + analytic model cells.

Two modes, both emitted to ``experiments/roofline.{md,json}``:

* **Kernel-bandwidth mode** (always runs; EXPERIMENTS.md §Roofline):
  times the combining kernels' XLA twins — ``heap_kmin`` (frontier
  search), ``sorted_merge`` (merge-compact), ``label_prop`` (one label
  iteration) — and reports achieved vs *measured* peak bandwidth.  The
  peak is the host stream-copy bandwidth measured on THIS container,
  not a device datasheet constant: on the XLA:CPU backend the v5e
  numbers below would make every fraction meaningless.  The XLA twins
  are what the CPU backend actually executes on the combining hot path
  (the Pallas kernels only run compiled on TPU; ``interpret=True``
  times the emulator, not the kernel), so these fractions steer kernel
  work with real data instead of CPU-container noise.

* **Dry-run cell mode** (opportunistic — needs ``repro.launch.dryrun``
  artifacts): three analytic terms per (arch × shape × mesh) cell —
  compute = FLOPs / (chips × 197e12), memory = HBM bytes/dev / 819e9,
  collective = link traffic/dev / 50e9 (TPU v5e: 197 TFLOP/s bf16,
  819 GB/s HBM, ~50 GB/s/link ICI), collectives parsed from the
  compiled HLO.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
from typing import Callable, Dict, List, Tuple

from benchmarks.flops import cell_cost

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def analyse_cell(rec: Dict) -> Dict:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    chips = rec["n_devices"]
    cost = cell_cost(arch, shape, n_chips=chips)

    t_compute = cost.flops_total / (chips * PEAK_FLOPS)
    t_memory = cost.hbm_bytes_per_dev / HBM_BW
    t_coll = rec["collectives"]["traffic_bytes_per_device"] / LINK_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-FLOPs time over the bound term
    t_model = cost.model_flops / (chips * PEAK_FLOPS)
    frac = t_model / bound if bound > 0 else 0.0

    return {
        "cell": rec["cell"], "arch": arch, "shape": shape, "mesh": mesh,
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": cost.model_flops,
        "hlo_flops_raw_per_dev": rec.get("flops", 0.0),
        "analytic_flops_total": cost.flops_total,
        "useful_ratio": cost.model_flops / max(cost.flops_total, 1.0),
        "roofline_fraction": frac,
        "mem_args_gib": rec["memory"]["argument_size_in_bytes"] / 2**30,
        "mem_temp_gib": rec["memory"]["temp_size_in_bytes"] / 2**30,
        "coll_count": rec["collectives"]["count"],
        "coll_by_kind": rec["collectives"]["by_kind"],
    }


def _fmt_s(x: float) -> str:
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


# ---------------------------------------------------------------------------
# Kernel-bandwidth mode (PR 9): achieved vs MEASURED peak bandwidth of the
# combining kernels' XLA twins (see module docstring for why twins + why a
# measured peak)
# ---------------------------------------------------------------------------
def _median_time(fn: Callable[[], object], *, repeats: int = 15,
                 warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def host_copy_bandwidth(mib: int = 64) -> float:
    """Measured host stream-copy bandwidth (bytes/s, read+write): the
    honest 'peak' for the backend this container runs on."""
    import numpy as np

    a = np.zeros(mib * 2**20 // 8, np.float64)
    t = _median_time(lambda: a.copy(), repeats=9, warmup=2)
    return 2 * a.nbytes / t


def kernel_cases() -> List[Tuple[str, str, int, Callable[[], object]]]:
    """(kernel, config, bytes_moved, jitted thunk) per combining kernel.

    ``bytes_moved`` is the minimal array footprint — every input array
    read once plus every output written once.  Gather/scatter traffic and
    scan temporaries are NOT counted, so ``achieved/peak`` is a lower
    bound on how hard the kernel drives the memory system."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.batched_pq import _k_smallest
    from repro.kernels.label_prop.ops import label_step_xla
    from repro.kernels.sorted_merge.ops import merge_compact_xla

    rng = np.random.default_rng(0)
    cases: List[Tuple[str, str, int, Callable[[], object]]] = []

    # heap_kmin: K-shard frontier search (PQ combining phase 1).  A
    # sorted ascending run is a valid 1-indexed min-heap (parent index <
    # child index ⇒ parent value ≤ child value); slot 0 is scratch.
    K, cap, c_max = 4, 1 << 15, 64
    heaps = jnp.asarray(
        np.sort(rng.random((K, cap)).astype(np.float32), axis=1))
    sizes = jnp.full((K,), cap - 1, jnp.int32)
    kmin = jax.jit(jax.vmap(
        lambda a, s: _k_smallest(a, s, jnp.int32(c_max), c_max)))
    jax.block_until_ready(kmin(heaps, sizes))
    cases.append((
        "heap_kmin", f"K={K} cap={cap} c_max={c_max}",
        K * cap * 4 + K * c_max * 8,
        lambda: jax.block_until_ready(kmin(heaps, sizes))))

    # sorted_merge: one merge-compact (PQ combining phase 4).  Evens in
    # the sorted run, odds in the insert run — disjoint, both strictly
    # increasing; C lanes dropped from A so the merge fits N.
    N, C = 1 << 15, 64
    a_keys = jnp.asarray((np.arange(N) * 2.0).astype(np.float32))
    a_vals = a_keys + 0.5
    a_keep = jnp.asarray(np.arange(N) < N - C)
    b_keys = jnp.asarray((np.arange(C) * 2.0 + 1.0).astype(np.float32))
    b_vals = b_keys + 0.5
    b_count = jnp.int32(C)
    merge = jax.jit(merge_compact_xla)
    jax.block_until_ready(merge(a_keys, a_vals, a_keep, b_keys, b_vals,
                                b_count))
    cases.append((
        "sorted_merge", f"N={N} C={C}",
        2 * N * 4 + N * 1 + 2 * C * 4 + 2 * N * 4,
        lambda: jax.block_until_ready(
            merge(a_keys, a_vals, a_keep, b_keys, b_vals, b_count))))

    # label_prop: one scatter-min + pointer-jump iteration (graph full
    # rebuild inner step) over a random edge multiset.
    n, E = 1 << 14, 1 << 15
    labels = jnp.arange(n, dtype=jnp.int32)
    eu = jnp.asarray(rng.integers(n, size=E).astype(np.int32))
    ev = jnp.asarray(rng.integers(n, size=E).astype(np.int32))
    lstep = jax.jit(label_step_xla)
    jax.block_until_ready(lstep(labels, eu, ev))
    cases.append((
        "label_prop", f"n={n} E={E}",
        n * 4 + 2 * E * 4 + n * 4,
        lambda: jax.block_until_ready(lstep(labels, eu, ev))))
    return cases


def kernel_roofline(repeats: int = 15) -> Dict:
    """Time every kernel case; returns the JSON-ready payload."""
    peak = host_copy_bandwidth()
    rows = []
    for name, cfg, nbytes, thunk in kernel_cases():
        t = _median_time(thunk, repeats=repeats)
        bw = nbytes / t
        rows.append({
            "kernel": name, "config": cfg, "bytes": nbytes,
            "median_s": t, "achieved_gbs": round(bw / 1e9, 3),
            "peak_gbs": round(peak / 1e9, 3),
            "fraction": round(bw / peak, 4),
        })
    return {"peak_gbs": round(peak / 1e9, 3), "kernels": rows}


def build_kernel_table(payload: Dict) -> str:
    rows = ["| kernel | config | bytes/call | median | achieved GB/s | "
            "peak GB/s | fraction |",
            "|---|---|---|---|---|---|---|"]
    for r in payload["kernels"]:
        rows.append(
            f"| {r['kernel']} | {r['config']} | {r['bytes']} "
            f"| {_fmt_s(r['median_s'])} | {r['achieved_gbs']:.2f} "
            f"| {r['peak_gbs']:.2f} | {r['fraction']:.3f} |")
    return "\n".join(rows)


def build_table(records: List[Dict]) -> str:
    rows = ["| cell | compute | memory | collective | dominant | useful | "
            "roofline-frac | args GiB | temp GiB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        rows.append(
            f"| {r['arch']}·{r['shape']}·{r['mesh']} "
            f"| {_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} "
            f"| {_fmt_s(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['mem_args_gib']:.2f} | {r['mem_temp_gib']:.2f} |")
    return "\n".join(rows)


def main(dryrun_dir: str = DRYRUN_DIR, mesh_filter: str = "16x16",
         out: str = None, repeats: int = 15):
    # kernel-bandwidth mode: always runs (it needs only this container)
    payload = kernel_roofline(repeats=repeats)
    ktable = build_kernel_table(payload)
    print(f"measured host copy bandwidth: {payload['peak_gbs']:.2f} GB/s")
    print(ktable)
    sections = [
        "# Roofline", "",
        "## Combining kernels — achieved vs measured peak bandwidth", "",
        f"Peak = host stream-copy bandwidth measured on this container "
        f"({payload['peak_gbs']:.2f} GB/s); bytes = minimal array "
        f"footprint (inputs read once + outputs written once).", "",
        ktable,
    ]
    # dry-run cell mode: opportunistic (needs launch.dryrun artifacts)
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        recs.append(analyse_cell(rec))
    payload["cells"] = recs
    if recs:
        table = build_table(recs)
        print(table)
        sections += ["", "## Dry-run cells (analytic, TPU v5e)", "", table]
        from collections import Counter
        doms = Counter(r["dominant"] for r in recs)
        print(f"\n{len(recs)} cells; dominant terms: {dict(doms)}")
        worst = sorted(recs, key=lambda r: r["roofline_fraction"])[:5]
        print("worst roofline fractions:",
              [(r["cell"], round(r["roofline_fraction"], 3))
               for r in worst])
    else:
        print("[roofline] no dry-run artifacts — kernel mode only "
              "(run `python -m repro.launch.dryrun --all --mesh both` "
              "for the cell table)")
    out = out or os.path.join(dryrun_dir, "..", "roofline.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    json.dump(payload, open(out, "w"), indent=1)
    with open(os.path.join(os.path.dirname(out), "roofline.md"), "w") as f:
        f.write("\n".join(sections) + "\n")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default=DRYRUN_DIR)
    ap.add_argument("--repeats", type=int, default=15)
    args = ap.parse_args()
    main(args.dir, args.mesh, repeats=args.repeats)
