"""Roofline analysis: three terms per (arch × shape × mesh) cell.

Sources (per EXPERIMENTS.md §Roofline):
  * compute term  = FLOPs / (chips × 197e12)        [analytic flops.py —
      cost_analysis undercounts scan bodies; calibrated vs unrolled HLO]
  * memory term   = HBM bytes / dev / 819e9          [analytic flops.py]
  * collective term = per-device link traffic / 50e9 [parsed from the
      compiled HLO of the dry-run — exact for the artifact we ship]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Reads experiments/dryrun/*.json, writes experiments/roofline.json and a
markdown table to stdout / experiments/roofline.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from benchmarks.flops import cell_cost

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def analyse_cell(rec: Dict) -> Dict:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    chips = rec["n_devices"]
    cost = cell_cost(arch, shape, n_chips=chips)

    t_compute = cost.flops_total / (chips * PEAK_FLOPS)
    t_memory = cost.hbm_bytes_per_dev / HBM_BW
    t_coll = rec["collectives"]["traffic_bytes_per_device"] / LINK_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-FLOPs time over the bound term
    t_model = cost.model_flops / (chips * PEAK_FLOPS)
    frac = t_model / bound if bound > 0 else 0.0

    return {
        "cell": rec["cell"], "arch": arch, "shape": shape, "mesh": mesh,
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": cost.model_flops,
        "hlo_flops_raw_per_dev": rec.get("flops", 0.0),
        "analytic_flops_total": cost.flops_total,
        "useful_ratio": cost.model_flops / max(cost.flops_total, 1.0),
        "roofline_fraction": frac,
        "mem_args_gib": rec["memory"]["argument_size_in_bytes"] / 2**30,
        "mem_temp_gib": rec["memory"]["temp_size_in_bytes"] / 2**30,
        "coll_count": rec["collectives"]["count"],
        "coll_by_kind": rec["collectives"]["by_kind"],
    }


def _fmt_s(x: float) -> str:
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def build_table(records: List[Dict]) -> str:
    rows = ["| cell | compute | memory | collective | dominant | useful | "
            "roofline-frac | args GiB | temp GiB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        rows.append(
            f"| {r['arch']}·{r['shape']}·{r['mesh']} "
            f"| {_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} "
            f"| {_fmt_s(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['mem_args_gib']:.2f} | {r['mem_temp_gib']:.2f} |")
    return "\n".join(rows)


def main(dryrun_dir: str = DRYRUN_DIR, mesh_filter: str = "16x16",
         out: str = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        recs.append(analyse_cell(rec))
    table = build_table(recs)
    print(table)
    out = out or os.path.join(dryrun_dir, "..", "roofline.json")
    json.dump(recs, open(out, "w"), indent=1)
    with open(os.path.join(os.path.dirname(out), "roofline.md"), "w") as f:
        f.write(table + "\n")
    # headline stats
    from collections import Counter
    doms = Counter(r["dominant"] for r in recs)
    print(f"\n{len(recs)} cells; dominant terms: {dict(doms)}")
    worst = sorted(recs, key=lambda r: r["roofline_fraction"])[:5]
    print("worst roofline fractions:",
          [(r["cell"], round(r["roofline_fraction"], 3)) for r in worst])
    return recs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default=DRYRUN_DIR)
    args = ap.parse_args()
    main(args.dir, args.mesh)
