"""Shared timing discipline for the benchmark modules (ISSUE 4 satellite).

The trajectory entries in BENCH_pq.json / BENCH_graph.json record 2–3×
run-to-run swings on the 2-core CPU container (EXPERIMENTS §Ablations) —
single-shot timings made every cross-PR comparison a coin flip.  Every
bench row now goes through :func:`measure`:

* one untimed **warmup** run (jit compilation + cache warm — the bench
  modules keep their own op-path warmups on top);
* ``repeats`` timed runs (default 5);
* the row reports the **median** ops/s plus the **IQR** (quartile spread,
  same unit) — a cheap robust dispersion that flags noisy cells without
  pretending the container can produce clean confidence intervals.

Rows keep ``ops_per_s`` as the median so downstream tooling (the CI
regression gate, the trajectory JSONs) needs no schema change; ``iqr``
rides along as a new field.
"""
from __future__ import annotations

import statistics
from typing import Callable, Dict

from .common import throughput


def median_iqr(samples) -> Dict[str, float]:
    """Robust summary of repeated samples: ``{"median", "iqr"}``.

    One sample degrades to ``iqr`` 0.0 (the quick-smoke escape hatch).
    The single source of the discipline — bench_serving shares it, so a
    change here cannot desynchronize the rows the CI gate compares.
    """
    samples = sorted(samples)
    if not samples:
        raise ValueError("need at least one sample")
    median = statistics.median(samples)
    if len(samples) >= 2:
        q = statistics.quantiles(samples, n=4, method="inclusive")
        iqr = q[2] - q[0]
    else:
        iqr = 0.0
    return {"median": median, "iqr": iqr}


def measure(n_threads: int, ops_per_thread: int,
            body: Callable[[int], None], *, repeats: int = 5,
            warmup: bool = True) -> Dict[str, float]:
    """Median-of-``repeats`` throughput of ``body`` across a thread group.

    Returns ``{"ops_per_s": median, "iqr": iqr}`` (both rounded to 0.1).
    """
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    if warmup:
        throughput(n_threads, ops_per_thread, body)
    stats = median_iqr(throughput(n_threads, ops_per_thread, body)
                       for _ in range(repeats))
    return {"ops_per_s": round(stats["median"], 1),
            "iqr": round(stats["iqr"], 1)}
