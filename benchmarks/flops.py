"""Analytic FLOP / HBM-byte model per (arch × shape) — the roofline's
compute and memory terms.

Why analytic: XLA's ``compiled.cost_analysis()`` counts a ``while``-loop
body ONCE, so any scan-over-layers program under-reports FLOPs by ~n_layers
(verified: qwen2 train_4k counts 3.89e12/dev scanned vs 1.62e13/dev
unrolled).  The dry-run artifact keeps the production scan (compact HLO);
FLOPs and bytes are derived here from the architecture arithmetic, and the
model is CALIBRATED against unrolled-compile cost_analysis for small cells
(see EXPERIMENTS.md §Roofline — agreement within ~10%).

Conventions:
  * matmul FLOPs = 2·m·n·k; a train step = fwd (1×) + bwd (2×) + remat
    re-fwd (1× when cfg.remat) over every weight matmul.
  * attention scores/PV = 4·S_q·S_kv_effective·H·hd per layer (2 matmuls),
    causal halves S_kv_effective; sliding window clamps it.
  * MoE: only routed-active expert FLOPs count (top_k + shared), i.e. the
    per-token active parameter set — capacity overflow drops are ignored
    (≤ a few % at cf 1.25).
  * MODEL_FLOPS = 6·N_active·D_tokens (2 fwd + 4 bwd per active param) —
    the "useful FLOPs" yardstick; ratio vs the full model catches
    remat/attention/router overheads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs import SHAPES, get
from repro.models.config import ArchConfig

BF16 = 2
F32 = 4


def _layer_matmul_params(cfg: ArchConfig, lspec) -> Dict[str, float]:
    """Per-layer matmul parameter count by component (active / total)."""
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out: Dict[str, float] = {"mixer": 0, "ffn_active": 0, "ffn_total": 0}
    m = lspec.mixer
    if m in ("full", "local"):
        out["mixer"] = D * H * hd + 2 * D * K * hd + H * hd * D
    elif m == "mla":
        a = cfg.mla
        q_dim = H * (a.nope_head_dim + a.rope_head_dim)
        out["mixer"] = (D * q_dim                       # q proj
                        + D * (a.kv_lora_rank + a.rope_head_dim)
                        + a.kv_lora_rank * H * (a.nope_head_dim
                                                + a.v_head_dim)
                        + H * a.v_head_dim * D)
    elif m == "rglru":
        R = cfg.d_rnn
        out["mixer"] = 2 * D * R + 2 * R * R + R * D + cfg.conv_width * R
    elif m == "rwkv6":
        out["mixer"] = 5 * D * D + 32 * D * 7            # rkvgo + loras
    if lspec.cross_attn:
        out["mixer"] += D * H * hd + 2 * D * K * hd + H * hd * D

    f = lspec.ffn
    F = cfg.d_ff
    if f == "moe":
        mm = cfg.moe
        per_exp = 3 * D * mm.d_ff
        shared = 3 * D * (mm.d_ff_shared or mm.d_ff) * mm.n_shared
        router = D * mm.n_experts
        out["ffn_total"] = mm.n_experts * per_exp + shared + router
        out["ffn_active"] = mm.top_k * per_exp + shared + router
    elif f == "rwkv_cm":
        out["ffn_active"] = out["ffn_total"] = 2 * D * F + D * D
    elif f == "glu":
        out["ffn_active"] = out["ffn_total"] = 3 * D * F
    else:
        out["ffn_active"] = out["ffn_total"] = 2 * D * F
    return out


def _attention_flops_fwd(cfg: ArchConfig, B: int, Sq: int, Skv: int,
                         decode: bool) -> float:
    """Scores+PV matmul FLOPs, all layers, forward."""
    H, hd = cfg.n_heads, cfg.head_dim
    total = 0.0
    for lspec in cfg.layer_specs:
        m = lspec.mixer
        if m in ("rglru", "rwkv6"):
            # linear state update: 2 FMA per state cell per token
            if m == "rwkv6":
                Hh = cfg.d_model // cfg.rwkv_head_dim
                total += 4 * B * Sq * Hh * cfg.rwkv_head_dim ** 2
            else:
                total += 6 * B * Sq * cfg.d_rnn
            continue
        if m == "mla":
            a = cfg.mla
            qk_dim = a.nope_head_dim + a.rope_head_dim
            v_dim = a.v_head_dim
        else:
            qk_dim = v_dim = hd
        if decode:
            eff = Skv
        elif lspec.window:
            # each query sees ≤ window keys (causal local)
            eff = min(Skv, lspec.window)
        elif cfg.causal and not lspec.cross_attn:
            eff = Skv / 2
        else:
            eff = Skv
        if lspec.cross_attn:
            eff = cfg.n_img_tokens
        total += 2 * B * Sq * eff * H * (qk_dim + v_dim)
    return total


@dataclass
class CellCost:
    flops_total: float          # whole step, all chips
    model_flops: float          # 6·N_active·D yardstick
    hbm_bytes_per_dev: float    # analytic HBM traffic per device
    n_active: float
    n_total: float


def n_params(cfg: ArchConfig) -> Dict[str, float]:
    """(active, total) matmul + embedding parameter counts."""
    active = total = 0.0
    for lspec in cfg.layer_specs:
        c = _layer_matmul_params(cfg, lspec)
        active += c["mixer"] + c["ffn_active"]
        total += c["mixer"] + c["ffn_total"]
    if cfg.n_prefix:
        D = cfg.d_model
        c = _layer_matmul_params(cfg, cfg.period[0])
        active += c["mixer"] + 3 * D * cfg.first_layer_ffn
        total += c["mixer"] + 3 * D * cfg.first_layer_ffn
    emb = cfg.vocab * cfg.d_model
    return {"active": active, "total": total, "embed": emb}


def cell_cost(arch_id: str, shape_name: str, n_chips: int = 256) -> CellCost:
    cfg = get(arch_id)
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    p = n_params(cfg)

    if sp.kind == "train":
        tokens = B * S
        # fwd(2) + bwd(4) + remat re-fwd(2 when on) per matmul param
        mm_factor = (2 + 4 + (2 if cfg.remat else 0))
        dense_flops = mm_factor * p["active"] * tokens
        # unembed matmul (tied embed): fwd+bwd(+remat is outside scan: no)
        head_flops = 6 * p["embed"] * tokens
        attn = _attention_flops_fwd(cfg, B, S, S, decode=False)
        attn_flops = attn * (3 + (1 if cfg.remat else 0))
        flops = dense_flops + head_flops + attn_flops
        model_flops = 6 * (p["active"] + p["embed"]) * tokens

        # HBM per device: params+grads+opt streamed once each way + acts
        np_dev = (p["total"] + p["embed"]) / n_chips
        param_traffic = np_dev * (BF16 * 3 + F32 * 4 * 2)   # p,g,bwd + m,v rw
        act = B * S * cfg.d_model * BF16 / n_chips
        act_traffic = act * cfg.n_layers * (2 if cfg.remat else 4)
        hbm = param_traffic + act_traffic
    elif sp.kind == "prefill":
        tokens = B * S
        dense_flops = 2 * p["active"] * tokens
        head_flops = 2 * p["embed"] * tokens
        attn_flops = _attention_flops_fwd(cfg, B, S, S, decode=False)
        flops = dense_flops + head_flops + attn_flops
        model_flops = 2 * (p["active"] + p["embed"]) * tokens
        np_dev = (p["total"] + p["embed"]) / n_chips
        act = B * S * cfg.d_model * BF16 / n_chips
        kv_write = _kv_cache_bytes(cfg, B, S) / n_chips
        hbm = np_dev * BF16 + act * cfg.n_layers * 2 + kv_write
    else:  # decode: one token against a cache of S
        tokens = B * 1
        dense_flops = 2 * p["active"] * tokens
        head_flops = 2 * p["embed"] * tokens
        attn_flops = _attention_flops_fwd(cfg, B, 1, S, decode=True)
        flops = dense_flops + head_flops + attn_flops
        model_flops = 2 * (p["active"] + p["embed"]) * tokens
        np_dev = (p["total"] + p["embed"]) / n_chips
        kv = _kv_cache_bytes(cfg, B, S) / n_chips
        hbm = np_dev * BF16 + kv                  # weights + full cache read
    return CellCost(flops_total=flops, model_flops=model_flops,
                    hbm_bytes_per_dev=hbm,
                    n_active=p["active"] + p["embed"],
                    n_total=p["total"] + p["embed"])


def _kv_cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    total = 0.0
    for lspec in cfg.layer_specs:
        m = lspec.mixer
        if m in ("full", "mla"):
            if m == "mla":
                a = cfg.mla
                per_tok = cfg.n_heads * (a.nope_head_dim + a.rope_head_dim
                                         + a.v_head_dim)
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
            total += B * S * per_tok * BF16
        elif m == "local":
            win = min(S, lspec.window + 1)
            total += B * win * 2 * cfg.n_kv_heads * cfg.head_dim * BF16
        elif m == "rglru":
            total += B * cfg.d_rnn * F32
        elif m == "rwkv6":
            Hh = cfg.d_model // cfg.rwkv_head_dim
            total += B * Hh * cfg.rwkv_head_dim ** 2 * F32
    return total
