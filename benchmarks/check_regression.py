"""CI perf-smoke regression gate (ISSUE 4 satellite).

Compares a fresh perf-smoke run (``experiments/bench/bench_<name>.json``,
written by the bench module that just ran in CI) against the LAST
trajectory entry recorded in the repo-root ``BENCH_<name>.json`` and fails
when any parallel-combining row's median throughput dropped by more than
``--threshold`` (default 50%).

Only device-tier ``PC*`` rows gate — the host-native calibration rows
(FC/Lock, and the graph bench's ``PC host`` tier) track the runner's
CPU, not this repo's hot path.  The ISSUE-9 megapass rows
(``PC-K4 megapass`` / ``PC-K4 alternating``, carrying
``rounds_per_dispatch``) ride the same identity keys: on their first
recorded run they surface as "new row (no baseline)" — informational,
the PR-5 convention — and gate like any PC row once a trajectory entry
records them.  The ISSUE-10 mesh rows (``PC-K{K} mesh``, carrying
``device_count``) follow the same convention: informational on their
first run, then gated per (impl, ..., device_count) so a D=4 row is
never compared against a D=8 one.  Rows whose recorded baseline IQR reaches
their median are reported as ``UNSTABLE`` (with the comparison they
would have made) and excluded from gating, plus a summary count — the
gate would only measure container noise there, but the exclusion must be
visible in the CI log, never silent.  Rows present in only one side (a
new ablation, a renamed impl)
are reported and skipped.  ``--warn-only`` turns failures into warnings
— CI passes it on forks, whose runners have no comparable perf history.

Usage:  PYTHONPATH=src python -m benchmarks.check_regression --bench pq
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

# row-identity fields per benchmark (ops_per_s is the compared value).
# pq/map carry "device_count" so the ISSUE-10 mesh rows ("PC-K4 mesh"
# etc., measured under forced multi-device worlds) key separately per
# world size; pre-mesh rows never set the field, so every historical
# key stays (..., None) on both sides and keeps gating unchanged.
KEYS = {
    "pq": ("impl", "size", "threads", "device_count"),
    "graph": ("impl", "workload", "read_pct", "threads"),
    "map": ("impl", "read_pct", "threads", "device_count"),
    "sketch": ("impl", "read_pct", "threads"),
    "unionfind": ("impl", "read_pct", "threads"),
}


def _gates(impl: str) -> bool:
    """Device-tier PC rows only: 'PC host'/'FC host' are host-tier
    calibration rows, not hot-path rows."""
    return impl.startswith("PC") and impl != "PC host"


def _index(rows, keys, faulted=None):
    """key -> (median, iqr_or_None) for every gating row.  Rows without
    an ``ops_per_s`` are skipped, never a KeyError — a malformed or
    informational row must not crash the gate.  Rows recorded under an
    active fault plan (truthy ``fault_plan`` field) measure injected
    faults, not the hot path — they are excluded from gating, but their
    keys are collected into ``faulted`` so the caller reports the
    exclusion loudly (the UNSTABLE convention: visible, never silent)."""
    out = {}
    for r in rows:
        if not _gates(str(r.get("impl", ""))) or "ops_per_s" not in r:
            continue
        key = tuple(r.get(k) for k in keys)
        if r.get("fault_plan"):
            if faulted is not None:
                faulted.append(key)
            continue
        out[key] = (
            float(r["ops_per_s"]),
            float(r["iqr"]) if "iqr" in r else None)
    return out


def check(bench: str, threshold: float = 0.5, warn_only: bool = False,
          fresh_path: str = None, baseline_path: str = None) -> int:
    if bench not in KEYS:
        raise ValueError(f"unknown bench {bench!r} (have {sorted(KEYS)})")
    keys = KEYS[bench]
    fresh_path = fresh_path or os.path.join(
        ROOT, "experiments", "bench", f"bench_{bench}.json")
    baseline_path = baseline_path or os.path.join(
        ROOT, f"BENCH_{bench}.json")
    faulted = []
    fresh = _index(json.load(open(fresh_path)), keys, faulted)
    try:
        traj = json.load(open(baseline_path))["trajectory"]
    except (FileNotFoundError, KeyError):
        traj = []
    for key in faulted:
        print(f"[perf-gate]   FAULT-PLAN {key}: recorded under an active "
              f"fault plan — NOT GATED (injected faults skew throughput)")
    if not traj:
        # a brand-new benchmark has no recorded history yet: its rows
        # are informational on their first run, not a hard failure
        print(f"[perf-gate] bench_{bench}: no baseline trajectory at "
              f"{baseline_path} — {len(fresh)} fresh PC row(s) recorded "
              f"informationally, nothing to gate")
        for key in sorted(fresh):
            print(f"[perf-gate]   new row (no baseline): {key}")
        return 0
    base_faulted = []
    base = _index(traj[-1]["rows"], keys, base_faulted)
    for key in base_faulted:
        print(f"[perf-gate]   FAULT-PLAN {key}: baseline row recorded "
              f"under an active fault plan — NOT GATED")
    print(f"[perf-gate] bench_{bench}: {len(fresh)} fresh PC rows vs "
          f"trajectory entry pr={traj[-1].get('pr')} "
          f"({len(base)} baseline rows)")
    failures = []
    unstable = []
    for key, (old, old_iqr) in sorted(base.items()):
        got = fresh.get(key)
        if got is None:
            print(f"[perf-gate]   skip (no fresh row): {key}")
            continue
        new = got[0]
        ratio = new / old if old > 0 else float("inf")
        if old_iqr is not None and old > 0 and old_iqr >= old:
            # baseline spread reaches the median: the cell measures
            # container noise, not the hot path — report it loudly as
            # UNSTABLE (with the comparison it would have made) instead
            # of silently dropping the row, so a gate that skips most of
            # its cells is visible in the CI log
            unstable.append(key)
            print(f"[perf-gate]   UNSTABLE   {key}: {old:.0f} -> "
                  f"{new:.0f} ops/s ({ratio:.2f}x) NOT GATED — baseline "
                  f"iqr {old_iqr:.0f} >= median {old:.0f}")
            continue
        flag = "REGRESSION" if ratio < (1.0 - threshold) else "ok"
        print(f"[perf-gate]   {flag:10s} {key}: {old:.0f} -> {new:.0f} "
              f"ops/s ({ratio:.2f}x)")
        if flag == "REGRESSION":
            failures.append((key, old, new))
    if unstable:
        print(f"[perf-gate] note: {len(unstable)} row(s) UNSTABLE "
              f"(baseline iqr >= median) — not gated; re-record the "
              f"trajectory entry with more --repeats to restore them")
    for key in sorted(set(fresh) - set(base)):
        print(f"[perf-gate]   new row (no baseline): {key}")
    compared = len(set(fresh) & set(base))
    if compared == 0 and not base:
        # the recorded entry has no gating rows at all (host-only or
        # informational first entry): nothing to compare, nothing broken
        print(f"[perf-gate] pass (baseline entry has no PC rows — "
              f"{len(fresh)} fresh row(s) informational)")
        return 0
    if compared == 0:
        # a silent no-op gate is worse than a failing one: this happens
        # when the CI smoke config drifts from the recorded trajectory
        msg = (f"no comparable rows between the fresh run and "
               f"BENCH_{bench}.json — regenerate the trajectory entry "
               f"with the CI smoke config")
        if warn_only:
            print(f"[perf-gate] WARNING (warn-only): {msg}")
            return 0
        print(f"[perf-gate] FAIL: {msg}")
        return 1
    if failures:
        msg = (f"{len(failures)} PC row(s) regressed >"
               f"{threshold:.0%} vs BENCH_{bench}.json")
        if warn_only:
            print(f"[perf-gate] WARNING (warn-only): {msg}")
            return 0
        print(f"[perf-gate] FAIL: {msg}")
        return 1
    print("[perf-gate] pass")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", choices=sorted(KEYS), required=True)
    ap.add_argument("--threshold", type=float, default=0.5,
                    help="fail when median drops by more than this "
                         "fraction (default 0.5 = 50%%)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (forks)")
    a = ap.parse_args(argv)
    return check(a.bench, threshold=a.threshold, warn_only=a.warn_only)


if __name__ == "__main__":
    sys.exit(main())
