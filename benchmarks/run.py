"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

Structure benchmarks AUTO-ENROLL from the workload registry
(``repro.core.substrate``, DESIGN.md §16): every registered
:class:`StructureSpec` with a ``bench`` module contributes one step,
driven by its ``bench_smoke`` quick-sweep argv — registering a new
structure adds its bench row here with zero edits to this file.  The
fixed steps (batch scaling, serving, roofline) follow.  ``--repeats``
plumbs the shared timing discipline (``benchmarks/_timing.py``: warmup +
median-of-N + IQR) through every row.
"""
from __future__ import annotations

import argparse
import importlib
import time

from repro.core import substrate


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=2,
                    help="timed repeats per bench row (median + IQR via "
                         "benchmarks._timing.measure)")
    args = ap.parse_args(argv)
    repeats = args.repeats

    enrolled = [s for s in substrate.specs() if s.bench]
    n_steps = len(enrolled) + 3
    t0 = time.time()

    step = 0
    for spec in enrolled:
        step += 1
        print("=" * 70)
        print(f"[{step}/{n_steps}] {spec.title or spec.name} "
              f"({spec.bench}, registry-enrolled)")
        print("=" * 70)
        mod = importlib.import_module(spec.bench)
        mod.main(list(spec.bench_smoke) + ["--repeats", str(repeats)])

    step += 1
    print("=" * 70)
    print(f"[{step}/{n_steps}] Thm.4 — batched heap cost scaling "
          f"O(c log c + log n)")
    print("=" * 70)
    from .bench_batch_scaling import bench_scaling
    bench_scaling(n_fixed=1 << 13, c_list=(2, 8, 32),
                  n_list=(1 << 10, 1 << 13, 1 << 16))

    step += 1
    print("=" * 70)
    print(f"[{step}/{n_steps}] Serving — PC scheduler vs serial dispatch")
    print("=" * 70)
    from .bench_serving import bench_serving
    bench_serving(session_counts=(1, 4), requests=2, tokens=4,
                  repeats=repeats)

    step += 1
    print("=" * 70)
    print(f"[{step}/{n_steps}] Roofline — measured kernel bandwidth "
          f"(+ dry-run cells when artifacts exist)")
    print("=" * 70)
    from .roofline import main as roofline_main
    roofline_main(repeats=max(repeats, 5))

    print(f"\n[benchmarks] all done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
