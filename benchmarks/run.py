"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure (quick settings — the full
sweeps are CLI flags on each module) plus the roofline aggregation over
the dry-run artifacts.  ``--repeats`` plumbs the shared timing
discipline (``benchmarks/_timing.py``: warmup + median-of-N + IQR)
through every row.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=2,
                    help="timed repeats per bench row (median + IQR via "
                         "benchmarks._timing.measure)")
    args = ap.parse_args(argv)
    repeats = args.repeats

    t0 = time.time()
    print("=" * 70)
    print("[1/6] Fig.2 — priority queue throughput (PC vs FC vs Lock)")
    print("=" * 70)
    from .bench_pq import bench_pq
    bench_pq(sizes=(20_000,), threads=(1, 2, 4), ops=150, repeats=repeats)

    print("=" * 70)
    print("[2/6] Fig.1 — dynamic graph throughput (PC vs Lock vs RW vs FC)")
    print("=" * 70)
    from .bench_graph import bench_graph
    bench_graph(n_vertices=300, read_pcts=(50, 100), threads=(1, 4),
                ops=60, repeats=repeats)

    print("=" * 70)
    print("[3/6] Batched ordered map (PC vs FC host, read-fraction sweep)")
    print("=" * 70)
    from .bench_map import bench_map
    bench_map(n_keys=1000, read_pcts=(50, 100), threads=(1, 4), ops=60,
              impls=("FC host", "PC-K1", "PC-K4"), repeats=repeats)

    print("=" * 70)
    print("[4/6] Thm.4 — batched heap cost scaling O(c log c + log n)")
    print("=" * 70)
    from .bench_batch_scaling import bench_scaling
    bench_scaling(n_fixed=1 << 13, c_list=(2, 8, 32),
                  n_list=(1 << 10, 1 << 13, 1 << 16))

    print("=" * 70)
    print("[5/6] Serving — PC scheduler vs serial dispatch")
    print("=" * 70)
    from .bench_serving import bench_serving
    bench_serving(session_counts=(1, 4), requests=2, tokens=4,
                  repeats=repeats)

    print("=" * 70)
    print("[6/6] Roofline — 3-term analysis over the dry-run artifacts")
    print("=" * 70)
    try:
        from .roofline import main as roofline_main
        roofline_main()
    except Exception as e:  # dry-run artifacts may be absent on a fresh tree
        print(f"[roofline] skipped: {e!r} — run "
              f"`python -m repro.launch.dryrun --all --mesh both` first")

    print(f"\n[benchmarks] all done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
